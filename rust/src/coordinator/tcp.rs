//! TCP front-end speaking **wire protocol v4**: newline-delimited JSON
//! for control and header frames, tensor payloads carried as
//! length-prefixed **binary frames** immediately following their JSON
//! header line (v3), and **content-addressed weights** (v4) — a
//! request may name its weight blob by hash instead of shipping it,
//! so a client ships each distinct blob to a server at most once per
//! server lifetime. This is the network face an edge gateway or a
//! remote coordinator ([`crate::backend::RemoteBackend`]) talks to, in
//! front of the same batcher + heterogeneous core pool the in-process
//! server uses.
//!
//! # Protocol specification
//!
//! Every frame *starts* with one JSON object terminated by `\n`. A
//! header that declares binary payload (`"bin"` on requests,
//! `"bin_output"` on replies) is followed by exactly that many raw
//! bytes before the next JSON line. Control frames (`hello`,
//! `ping`/`pong`, errors, `rejected`) are pure JSON lines, unchanged
//! from v2.
//!
//! ## `hello` (server → client, first line after connect)
//!
//! The server introduces itself before reading anything, advertising
//! every pool worker's capability so a remote coordinator can mask and
//! weigh this peer honestly:
//!
//! ```text
//! <- {"hello":{"proto":4,"ping":true,"bin":true,"wcache":true,"trace":true,
//!      "freq_hz":112000000,
//!      "cores":3,"workers":[
//!      {"backend":"sim-ipcore-i32","standard":true,"depthwise":true,
//!       "pointwise":true,"accum":"i32","model":"sim-cycles","quote":6272},
//!      ...]}}
//! ```
//!
//! `proto` is the protocol revision: 4 for a current endpoint, 2 for
//! a legacy endpoint ([`CoordinatorConfig::wire_v2_only`]). Clients
//! must accept either and key framing off the `"bin"` flag and weight
//! caching off the `"wcache"` flag (below), rejecting anything else.
//! `model` is the worker's
//! cost-model family ([`crate::backend::CostModel::family_tag`]) — a
//! remote coordinator prices this pool's compute by its fastest
//! advertised tier, so a host-workers-only peer is never mistaken for
//! a rack of IP cores. `workers` length is the peer's **worker
//! width**: a pipelining client may divide its compute quote by it
//! ([`crate::backend::CostModel::Remote`]). `quote` is the worker's
//! own cost-model estimate for the reference [`QUICKSTART`] standard
//! job, in that backend's own units — observability for the mix, not a
//! cross-backend comparable number.
//!
//! ## request (client → server)
//!
//! JSON-tensor form (v2, still accepted by every server):
//!
//! ```text
//! -> {"id":1,"spec":{"c":8,"h":16,"w":16,"k":8},"seed":42}
//! -> {"id":2,"kind":"depthwise","spec":{"c":8,"h":10,"w":10,"k":8,"relu":true},
//!     "seed":7,"full_output":true}
//! -> {"id":3,"kind":"pointwise","spec":{...},"img":[...C*H*W u8...],
//!     "weights":[...K*C*9 u8...],"bias":[...K i32...]}
//! ```
//!
//! Binary-tensor form (v3, only after the hello advertised
//! `"bin":true`):
//!
//! ```text
//! -> {"id":3,"kind":"standard","spec":{...},"full_output":true,
//!     "bin":[IMG_BYTES,WEIGHT_BYTES,BIAS_BYTES]}\n
//!    <IMG_BYTES raw u8><WEIGHT_BYTES raw u8><BIAS_BYTES i32 little-endian>
//! ```
//!
//! `"bin"` declares the exact byte length of the three tensor bodies
//! that follow the newline, in order: image (`C*H*W` u8), weights
//! (`K*C*9` u8 standard/pointwise, `C*9` depthwise), bias (`out_ch`
//! i32 words, little-endian, so `out_ch*4` bytes). A request carries
//! tensors either inline as JSON arrays *or* as a binary frame, never
//! both; `"bin"` wins if both appear.
//!
//! Content-addressed form (v4, only after the hello advertised
//! `"wcache":true`): a request may carry `"weights_hash"` — the
//! FNV-1a hash of the raw weight bytes — *instead of* the weight
//! payload. A binary frame declares a zero-length weights body, a
//! JSON-tensor request simply omits `"weights"`:
//!
//! ```text
//! -> {"id":4,"kind":"standard","spec":{...},"weights_hash":123456,
//!     "bin":[IMG_BYTES,0,BIAS_BYTES]}\n<IMG_BYTES raw u8><BIAS_BYTES i32 LE>
//! ```
//!
//! The server keeps a content-addressed LRU **weight store**
//! ([`crate::store::WeightStore`], budgeted in BRAM36 blocks against
//! the board's inventory — [`CoordinatorConfig::weight_store_bram36`]).
//! A hash-only request whose blob is resident is served from the
//! store; an unknown hash is answered immediately with a
//! `need_weights` frame (below), and the client re-sends the same
//! request once with the weights inline — still carrying
//! `"weights_hash"`, which both verifies the bytes and admits the
//! blob into the store for every later request on *any* connection to
//! this server. Inline weights whose declared hash does not match
//! their bytes are a per-job error (the connection survives). Plain
//! v2/v3 requests (no `"weights_hash"`) never touch the store.
//!
//! * `kind` — `"standard"` (default), `"depthwise"` (weights `C*9`,
//!   bias `C`, requires `k == c`; ReLU fuses when `spec.relu`), or
//!   `"pointwise"` (a 1×1 conv pre-lowered to the 3×3 dataflow:
//!   padded image + centre-tapped weights, standard shapes on the
//!   wire). Pointwise jobs need explicit tensors — there is no
//!   synthetic pointwise generator.
//! * `seed` — synthesise deterministic tensors server-side (load
//!   generation); explicit `img`/`weights`/`bias` or a `bin` frame
//!   carry real data.
//! * `full_output` — opt into the whole output tensor in the reply.
//!   Off by default: a load generator only needs the checksum.
//! * `trace` — distributed-tracing propagation (telemetry), only after
//!   the hello advertised `"trace":true`: the originating front's
//!   trace id for this job, a nonzero u64. A traced request's reply
//!   carries the server-side `queue_us`/`compute_us` decomposition
//!   (below), and a server running its own span sink records this
//!   hop's spans under the propagated id. Untraced requests omit the
//!   field entirely; clients must never send it to an endpoint whose
//!   hello lacks the flag (a v2-only endpoint also ignores a stray
//!   one).
//!
//! The wire serves production traffic only: every job requires I32
//! accumulator semantics (wrap-8 replies stay an in-process,
//! experiment-side concern).
//!
//! ## reply (server → client)
//!
//! ```text
//! <- {"id":1,"ok":true,"kind":"standard","core":0,"backend":"sim-ipcore-i32",
//!     "compute_cycles":6272,"total_cycles":6272,"sim_us":56,
//!     "weights_reused":false,"output_head":[...8 words...],"checksum":1234567}
//! <- {"id":2,"ok":true,...,"shape":[8,8,8],"output":[...i32 words...]}
//! <- {"id":3,"ok":true,...,"shape":[8,8,8],"bin_output":2048}\n<2048 bytes i32 LE>
//! ```
//!
//! A traced request's reply (and only a traced one) additionally
//! carries `"queue_us"` and `"compute_us"`: how long the job sat in
//! this server's dispatch queue and how long its backend call took,
//! both in microseconds of server wall time. The client subtracts both
//! from its measured round trip to get the pure wire component.
//!
//! `shape` plus `output` *or* `bin_output` appear only when the
//! request set `full_output`; the reply encoding mirrors the request
//! encoding (a binary-framed request gets a binary-framed reply, a
//! JSON-tensor request gets the v2 JSON array — that mirror **is** the
//! v2 compatibility path, no per-connection mode bit exists). `id` and
//! `checksum` are exact JSON integers: values above 2^53 must survive
//! the wire bit-identically, so emitters must never round-trip them
//! through f64. The checksum (sum of output words mod 2^31) always
//! lets clients verify numerics without shipping whole feature maps
//! back.
//!
//! ## error (server → client)
//!
//! ```text
//! <- {"id":9,"ok":false,"error":"spec violates §4.1 (K%4!=0 or too small)"}
//! ```
//!
//! Malformed JSON, bad shapes, unservable kinds and *backend failures*
//! (e.g. this peer's own remote sub-peer dropping) all answer with an
//! error frame on the same id — a request never silently disappears.
//! Binary framing adds a severity split:
//!
//! * body lengths that parse and fit the frame cap but are wrong for
//!   the spec — the server consumes exactly the declared bytes, errors
//!   the one job, and the **connection survives** (stream stays in
//!   sync);
//! * a `bin` declaration that exceeds [`MAX_BIN_BYTES`] or does not
//!   parse as three byte counts — error frame, then the server severs
//!   the connection (it cannot know where the next header starts);
//! * a binary frame sent to a v2-only endpoint — the server consumes
//!   the declared bytes and answers a clean "binary framing not
//!   negotiated" error; the connection survives and keeps serving
//!   JSON-tensor requests.
//!
//! ## rejected (server → client)
//!
//! ```text
//! <- {"id":9,"ok":false,"rejected":true,
//!     "error":"admission: 2048 PSUMs would exceed the in-flight budget"}
//! ```
//!
//! Load shedding. When the server runs with an in-flight PSUM budget
//! ([`CoordinatorConfig::max_inflight_psums`]) and a request's cost
//! quote would blow it, the server answers *immediately* with
//! `"rejected":true` instead of queueing — the fast-error admission
//! answer. Clients that predate the field still see a well-formed
//! error frame (`ok:false`, same id); the extra key is ignored.
//!
//! ## `need_weights` (server → client) — v4
//!
//! ```text
//! <- {"id":4,"ok":false,"need_weights":true,"weights_hash":123456,
//!     "error":"weights 123456 not resident; re-send inline with weights_hash"}
//! ```
//!
//! The fast-miss answer to a hash-only request whose blob is not in
//! the weight store — sent before admission control, so a miss never
//! burns a queue slot. `ok:false` plus the standard `error` field
//! keeps pre-v4 clients well-formed (they just see a failed job); a
//! v4 client re-sends the request once with the blob inline.
//! Residency is per server lifetime and LRU-bounded: a restarted
//! server has an empty store, so clients must drop their known-hash
//! sets whenever they redial, and an evicted blob simply round-trips
//! through one more `need_weights` → inline re-ship.
//!
//! ## `ping` (client → server) / `pong` (server → client) — negotiated
//!
//! ```text
//! -> {"ping":1}
//! <- {"pong":1}
//! ```
//!
//! Lightweight health probe (no `id`, echoes the ping's sequence
//! number). Feature-negotiated via the hello: a server that answers
//! pings advertises `"ping":true` inside its `hello` object; clients
//! must not send `ping` frames to peers whose hello lacks the flag.
//! Pings are answered before admission control — probing a saturated
//! server must not be shed — and jump the pipeline (the pong may
//! overtake queued replies).
//!
//! # Pipelining
//!
//! Requests on one connection are **pipelined**: the server dispatches
//! each request as soon as its frame is read, without waiting for
//! earlier replies, and writes replies as jobs complete. Consequences
//! clients must honour:
//!
//! * replies are **id-matched, not ordered** — a connection that has
//!   `n` requests in flight may see their replies in any interleaving
//!   (a v2-style client that submits one request and blocks for its
//!   reply is trivially unaffected);
//! * the server bounds the per-connection in-flight window at
//!   [`MAX_CONN_INFLIGHT`] jobs — beyond it the server simply stops
//!   reading the socket, so TCP backpressure propagates to the client;
//! * client request `id`s should be unique among that connection's
//!   in-flight requests (the server keys internally and echoes the
//!   client id verbatim, but duplicate in-flight ids make the replies
//!   indistinguishable to the *client*).
//!
//! # Version negotiation
//!
//! Hello flags — not the `proto` number — are the capability
//! switches: `"bin":true` negotiates binary tensor framing,
//! `"wcache":true` negotiates content-addressed weights, and
//! `"trace":true` negotiates trace propagation. Clients must send
//! JSON tensors to an endpoint whose hello lacks `bin`, must never
//! send `weights_hash` to one whose hello lacks `wcache`, and must
//! never send `trace` to one whose hello lacks `trace`.
//! `proto` is 4 on current endpoints and 2 on legacy
//! ([`CoordinatorConfig::wire_v2_only`]) endpoints; clients accept
//! both (outputs are bit-identical on every revision — only the
//! encoding differs). Capabilities *within* a revision are negotiated
//! by hello-field presence (`"ping":true`, `"bin":true`,
//! `"wcache":true`, `"trace":true` today): unknown hello fields,
//! unknown request fields and unknown reply fields must all be
//! ignored, so a newer server interoperates with an older client and
//! vice versa.
//!
//! # Shutdown
//!
//! The accept loop blocks in `accept()` (no poll sleep);
//! [`TcpServer::stop`] wakes it with a throwaway connection after
//! flipping the listener non-blocking, then drains: it joins every
//! per-connection reader (readers poll the shutdown flag on a read
//! timeout, so an idle keep-alive connection cannot block shutdown),
//! each reader joins its reply collector (in-flight jobs are answered
//! first), and only then does the worker pool shut down.

use super::backpressure::{Admission, AdmissionController, Policy};
use super::config::CoordinatorConfig;
use super::dispatch::CorePool;
use super::request::{fnv1a_bytes, weights_fingerprint_salted, ConvJob, ConvResult, Submission};
use crate::backend::JobKind;
use crate::model::{LayerSpec, Tensor, QUICKSTART};
use crate::store::WeightStore;
use crate::util::json::Json;
use std::collections::HashMap;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::channel;
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

/// Protocol revision advertised in the `hello` frame of a current
/// (binary-framing + weight-caching) endpoint.
pub const PROTO_VERSION: u64 = 4;

/// Legacy revision advertised by [`CoordinatorConfig::wire_v2_only`]
/// endpoints (JSON tensors only). Clients accept both.
pub const PROTO_V2: u64 = 2;

/// How often blocked connection readers wake to poll the shutdown flag.
const SHUTDOWN_POLL: Duration = Duration::from_millis(100);

/// Ceiling on one reply write; a client that stops draining its socket
/// loses the connection instead of wedging the handler thread.
const WRITE_TIMEOUT: Duration = Duration::from_secs(30);

/// Ceiling on zero-progress time inside a declared binary body; a
/// client that sends a bin header then stalls loses the connection
/// instead of pinning the handler thread and its buffers.
const BIN_READ_TIMEOUT: Duration = WRITE_TIMEOUT;

/// Hard cap on one wire frame. An S52 `full_output` reply is ~5 MB of
/// JSON text, so 64 MB never trips legitimately — it bounds memory (and
/// guarantees eventual termination) against a peer that streams bytes
/// without ever sending a newline, which would otherwise defeat the
/// read-timeout shutdown poll and grow the line buffer forever.
pub(crate) const MAX_LINE_BYTES: usize = 64 << 20;

/// Hard cap on the *declared* byte total of one binary tensor frame
/// (img + weights + bias). A declaration above it is unrecoverable by
/// construction — the server will not consume it, so it answers an
/// error frame and severs the connection.
pub(crate) const MAX_BIN_BYTES: usize = 64 << 20;

/// Per-connection pipelining window: the server stops reading a
/// connection's socket while this many of its jobs are in flight, so
/// TCP backpressure (not memory growth) is what a flooding client
/// feels. Generous relative to any single peer's worker width.
pub(crate) const MAX_CONN_INFLIGHT: usize = 64;

/// Outcome of one bounded line read.
pub(crate) enum LineRead {
    /// A full line is buffered in `buf` (newline consumed, excluded).
    Line,
    /// Clean end of stream.
    Eof,
}

/// `read_line` with a hard byte cap, accumulating into `buf` across
/// calls: a read timeout surfaces as `Err` (`WouldBlock`/`TimedOut`)
/// with every byte read so far preserved in `buf`, so retrying
/// continues the same line; a line longer than `cap` fails with
/// `InvalidData` instead of growing without bound.
pub(crate) fn read_line_capped<R: BufRead>(
    r: &mut R,
    buf: &mut Vec<u8>,
    cap: usize,
) -> std::io::Result<LineRead> {
    loop {
        let (found, n) = {
            let available = r.fill_buf()?;
            if available.is_empty() {
                return Ok(LineRead::Eof);
            }
            match available.iter().position(|&b| b == b'\n') {
                Some(i) => {
                    buf.extend_from_slice(&available[..i]);
                    (true, i + 1)
                }
                None => {
                    buf.extend_from_slice(available);
                    (false, available.len())
                }
            }
        };
        r.consume(n);
        if found {
            return Ok(LineRead::Line);
        }
        if buf.len() > cap {
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                format!("wire frame exceeds {cap} bytes without a newline"),
            ));
        }
    }
}

/// `read_exact` over a timeout-polled stream: `WouldBlock`/`TimedOut`
/// retries (re-checking the shutdown and chaos flags each lap, so a
/// stopping server never hangs mid-frame on a stalled client), EOF
/// inside the frame is an error, shutdown surfaces as `Interrupted`.
/// A frame that makes no progress for [`BIN_READ_TIMEOUT`] fails with
/// `TimedOut`: a client that declares a binary body and then stalls
/// would otherwise pin this handler thread and up to [`MAX_BIN_BYTES`]
/// of allocated buffers until server shutdown. The deadline resets on
/// every received byte, so slow-but-live senders are never cut off.
fn read_exact_polled<R: Read>(
    r: &mut R,
    buf: &mut [u8],
    shutdown: &AtomicBool,
    down: &AtomicBool,
) -> std::io::Result<()> {
    let mut filled = 0;
    let mut last_progress = std::time::Instant::now();
    while filled < buf.len() {
        if shutdown.load(Ordering::Relaxed) || down.load(Ordering::Relaxed) {
            return Err(std::io::Error::new(
                std::io::ErrorKind::Interrupted,
                "shutdown during binary frame",
            ));
        }
        match r.read(&mut buf[filled..]) {
            Ok(0) => {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::UnexpectedEof,
                    "eof inside binary frame",
                ))
            }
            Ok(n) => {
                filled += n;
                last_progress = std::time::Instant::now();
            }
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                if last_progress.elapsed() >= BIN_READ_TIMEOUT {
                    return Err(std::io::Error::new(
                        std::io::ErrorKind::TimedOut,
                        "stalled mid binary frame",
                    ));
                }
            }
            Err(e) => return Err(e),
        }
    }
    Ok(())
}

/// Encode i32 words as the wire's little-endian binary body.
pub(crate) fn encode_i32_le(words: &[i32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(words.len() * 4);
    for w in words {
        out.extend_from_slice(&w.to_le_bytes());
    }
    out
}

/// Decode a little-endian i32 binary body (trailing partial word
/// ignored — callers validate the byte length first).
pub(crate) fn decode_i32_le(bytes: &[u8]) -> Vec<i32> {
    bytes
        .chunks_exact(4)
        .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect()
}

/// Encode one complete explicit-tensor request frame — header line
/// plus, when `bin`, the three binary bodies — ready for a single
/// buffered write. Shared by [`crate::backend::RemoteBackend`]'s
/// pipelined writer and the wire tests, so client and server agree on
/// the framing by construction.
pub(crate) fn encode_request_frame(
    id: u64,
    kind: JobKind,
    spec: &LayerSpec,
    img: &[u8],
    weights: &[u8],
    bias: &[i32],
    full_output: bool,
    bin: bool,
) -> Vec<u8> {
    encode_request_frame_v4(id, kind, spec, img, Some(weights), None, bias, full_output, bin, 0)
}

/// v4 generalisation of [`encode_request_frame`]: `weights` may be
/// absent (a hash-only request — the binary frame declares a
/// zero-length weights body, the JSON form omits `"weights"`), and a
/// claimed `weights_hash` may ride along with or without the payload.
/// Callers must pass `weights_hash` when `weights` is `None` and must
/// only do either against a peer whose hello advertised
/// `"wcache":true`. `trace` is the propagated trace id (0 = untraced,
/// field omitted); callers must pass 0 unless the peer's hello
/// advertised `"trace":true`.
#[allow(clippy::too_many_arguments)]
pub(crate) fn encode_request_frame_v4(
    id: u64,
    kind: JobKind,
    spec: &LayerSpec,
    img: &[u8],
    weights: Option<&[u8]>,
    weights_hash: Option<u64>,
    bias: &[i32],
    full_output: bool,
    bin: bool,
    trace: u64,
) -> Vec<u8> {
    debug_assert!(
        weights.is_some() || weights_hash.is_some(),
        "a request needs weight bytes, a weight hash, or both"
    );
    let mut spec_fields = vec![
        ("c", Json::uint(spec.c as u64)),
        ("h", Json::uint(spec.h as u64)),
        ("w", Json::uint(spec.w as u64)),
        ("k", Json::uint(spec.k as u64)),
    ];
    if spec.relu {
        spec_fields.push(("relu", Json::Bool(true)));
    }
    let mut fields = vec![
        ("id", Json::uint(id)),
        ("kind", Json::str(kind.tag())),
        ("spec", Json::obj(spec_fields)),
    ];
    if full_output {
        fields.push(("full_output", Json::Bool(true)));
    }
    if let Some(h) = weights_hash {
        fields.push(("weights_hash", Json::uint(h)));
    }
    if trace != 0 {
        fields.push(("trace", Json::uint(trace)));
    }
    if bin {
        let wts = weights.unwrap_or(&[]);
        let bias_bytes = encode_i32_le(bias);
        fields.push((
            "bin",
            Json::arr_u64([
                img.len() as u64,
                wts.len() as u64,
                bias_bytes.len() as u64,
            ]),
        ));
        let header = Json::obj(fields).to_json();
        let mut out = Vec::with_capacity(
            header.len() + 1 + img.len() + wts.len() + bias_bytes.len(),
        );
        out.extend_from_slice(header.as_bytes());
        out.push(b'\n');
        out.extend_from_slice(img);
        out.extend_from_slice(wts);
        out.extend_from_slice(&bias_bytes);
        out
    } else {
        fields.push(("img", Json::arr_u64(img.iter().map(|&v| v as u64))));
        if let Some(wts) = weights {
            fields.push(("weights", Json::arr_u64(wts.iter().map(|&v| v as u64))));
        }
        fields.push(("bias", Json::arr_i64(bias.iter().map(|&b| b as i64))));
        let mut out = Json::obj(fields).to_json().into_bytes();
        out.push(b'\n');
        out
    }
}

/// The three raw tensor bodies of one binary request frame, exactly as
/// read off the wire (bias still i32-LE bytes — decoded and validated
/// in [`job_from_request`]).
pub(crate) struct BinTensors {
    pub img: Vec<u8>,
    pub weights: Vec<u8>,
    pub bias: Vec<u8>,
}

/// Parse a request header's `"bin"` declaration into the three body
/// byte lengths. `Ok(None)` — no binary frame. `Err` — the declaration
/// is unusable, and since the server then cannot know how many bytes
/// follow the header, the caller must sever the connection.
fn parse_bin_lens(req: &Json) -> Result<Option<[usize; 3]>, String> {
    let Some(b) = req.get(&["bin"]) else {
        return Ok(None);
    };
    let arr = b
        .as_arr()
        .filter(|a| a.len() == 3)
        .ok_or("bin must be [img,weights,bias] byte lengths")?;
    let mut lens = [0usize; 3];
    for (i, v) in arr.iter().enumerate() {
        lens[i] = v
            .as_u64()
            .and_then(|n| usize::try_from(n).ok())
            .ok_or_else(|| format!("bin[{i}] is not a byte count"))?;
    }
    Ok(Some(lens))
}

/// Running TCP server handle.
pub struct TcpServer {
    pub addr: std::net::SocketAddr,
    /// Kept so [`Self::stop`] can flip the listener non-blocking before
    /// nudging the blocking `accept()` awake.
    listener: Arc<TcpListener>,
    listener_thread: std::thread::JoinHandle<()>,
    shutdown: Arc<AtomicBool>,
    /// Chaos switch: while set, the accept loop drops new connections
    /// and [`Self::set_down`] has severed every live one.
    down: Arc<AtomicBool>,
    /// Per-connection handler threads, tracked so [`Self::stop`] can
    /// drain them instead of racing detached threads.
    conns: Arc<Mutex<Vec<std::thread::JoinHandle<()>>>>,
    /// One monitor clone per live connection's socket, registered
    /// *before* the handler greets the client, so [`Self::set_down`]
    /// can sever every connection a client has seen a hello on. Each
    /// handler holds its monitor's other `Arc` until it exits, which is
    /// how the listener prunes dead entries (`strong_count == 1`).
    live: Arc<Mutex<Vec<Arc<TcpStream>>>>,
    /// In-flight PSUM budget (admission control), present when the
    /// config sets `max_inflight_psums`.
    admission: Option<Arc<AdmissionController>>,
    /// Serve as a legacy v2 endpoint (see [`CoordinatorConfig::wire_v2_only`]).
    v2_only: bool,
    /// Content-addressed weight store shared by every connection (v4);
    /// `None` on a v2-only endpoint. Residency is per server lifetime:
    /// the store dies with the server, which is why clients drop their
    /// known-hash sets on redial.
    store: Option<Arc<WeightStore>>,
    pool: Arc<CorePool>,
}

fn parse_spec(j: &Json) -> Result<LayerSpec, String> {
    let g = |k: &str| {
        j.get(&[k])
            .and_then(Json::as_usize)
            .ok_or_else(|| format!("spec.{k} missing"))
    };
    let mut spec = LayerSpec::new(g("c")?, g("h")?, g("w")?, g("k")?);
    if j.get(&["relu"]).and_then(Json::as_bool).unwrap_or(false) {
        spec = spec.with_relu();
    }
    Ok(spec)
}

fn parse_kind(req: &Json) -> Result<JobKind, String> {
    match req.get(&["kind"]).and_then(Json::as_str) {
        None => Ok(JobKind::Standard),
        // One mapping, shared with the emit side: JobKind::tag().
        Some(s) => [
            JobKind::Standard,
            JobKind::Depthwise,
            JobKind::PointwiseAs3x3,
        ]
        .into_iter()
        .find(|k| k.tag() == s)
        .ok_or_else(|| format!("unknown kind '{s}' (expect standard|depthwise|pointwise)")),
    }
}

fn parse_u8_array(j: &Json, want_len: usize, name: &str) -> Result<Vec<u8>, String> {
    let arr = j.as_arr().ok_or_else(|| format!("{name} must be an array"))?;
    if arr.len() != want_len {
        return Err(format!("{name} length {} != {want_len}", arr.len()));
    }
    arr.iter()
        .map(|v| {
            v.as_f64()
                .filter(|n| (0.0..=255.0).contains(n))
                .map(|n| n as u8)
                .ok_or_else(|| format!("{name} element out of u8 range"))
        })
        .collect()
}

/// How one request's weights travelled, for the server's wire-level
/// accounting (counted into [`super::metrics::Metrics`] by the
/// connection handler, not here).
pub(crate) enum WireCache {
    /// Seed-synthetic request: no weight bytes on the wire at all.
    Untracked,
    /// Inline weights arrived — `bytes` of payload crossed the wire.
    /// `cached` when a v4 client also claimed the hash and the blob
    /// was offered to the store.
    Shipped { bytes: u64, cached: bool },
    /// Hash-only request served from the store: `bytes` of weight
    /// payload never crossed the wire.
    Hit { bytes: u64 },
}

/// Outcome of parsing one request frame.
pub(crate) enum Parsed {
    /// A dispatchable job, plus how its weights travelled.
    Job(Box<ConvJob>, WireCache),
    /// Hash-only request for a blob the store does not hold: answer
    /// with a `need_weights` frame instead of dispatching.
    NeedWeights(u64),
}

/// Build a ConvJob from one request — header JSON plus, for a
/// binary-framed request, the already-consumed tensor bodies. `id` is
/// the server's internal job id (client ids are echoed at reply-render
/// time, never used as dispatch keys — two pipelined clients reusing
/// ids must not collide). `store` is the endpoint's weight store
/// (`None` on a v2-only endpoint): hash-only requests resolve against
/// it, inline-with-hash requests populate it.
fn job_from_request(
    id: u64,
    req: &Json,
    bin: Option<BinTensors>,
    store: Option<&WeightStore>,
) -> Result<Parsed, String> {
    let spec = parse_spec(req.get(&["spec"]).ok_or("missing spec")?)?;
    let kind = parse_kind(req)?;
    match kind {
        JobKind::Standard | JobKind::PointwiseAs3x3 => {
            if !spec.paper_compatible() {
                return Err(format!("spec violates §4.1 (K%4!=0 or too small): {spec:?}"));
            }
        }
        JobKind::Depthwise => {
            if spec.k != spec.c {
                return Err(format!("depthwise spec needs K == C: {spec:?}"));
            }
            if spec.h < 3 || spec.w < 3 {
                return Err(format!("depthwise spec too small for a 3x3 window: {spec:?}"));
            }
        }
    }
    // Output-channel count: K for standard/pointwise, C for depthwise.
    let out_ch = match kind {
        JobKind::Depthwise => spec.c,
        _ => spec.k,
    };
    let weight_len = match kind {
        JobKind::Depthwise => spec.c * 9,
        _ => spec.k * spec.c * 9,
    };
    // Content-addressing (v4): a claimed hash can stand in for the
    // weight payload, or ride along with it to populate the store.
    let claimed_hash = req.get(&["weights_hash"]).and_then(Json::as_u64);
    // Explicit tensors, from either encoding: (img u8, weights u8 or
    // hash-only None, bias i32) validated against the spec.
    let explicit: Option<(Vec<u8>, Option<Vec<u8>>, Vec<i32>)> = if let Some(bt) = bin {
        let want_img = spec.c * spec.h * spec.w;
        if bt.img.len() != want_img {
            return Err(format!("bin img length {} != {want_img}", bt.img.len()));
        }
        let wts = if bt.weights.is_empty() && claimed_hash.is_some() {
            // v4 hash-only frame: a declared zero-length weights body.
            None
        } else {
            if bt.weights.len() != weight_len {
                return Err(format!(
                    "bin weights length {} != {weight_len}",
                    bt.weights.len()
                ));
            }
            Some(bt.weights)
        };
        if bt.bias.len() != out_ch * 4 {
            return Err(format!(
                "bin bias length {} != {} ({out_ch} i32 LE words)",
                bt.bias.len(),
                out_ch * 4
            ));
        }
        Some((bt.img, wts, decode_i32_le(&bt.bias)))
    } else if let Some(img_j) = req.get(&["img"]) {
        let img = parse_u8_array(img_j, spec.c * spec.h * spec.w, "img")?;
        let wts = match req.get(&["weights"]) {
            Some(w) => Some(parse_u8_array(w, weight_len, "weights")?),
            // v4 hash-only JSON form: `weights` omitted entirely.
            None if claimed_hash.is_some() => None,
            None => return Err("missing weights".into()),
        };
        let bias_arr = req
            .get(&["bias"])
            .and_then(Json::as_arr)
            .ok_or("missing bias")?;
        if bias_arr.len() != out_ch {
            return Err(format!("bias length {} != {}", bias_arr.len(), out_ch));
        }
        let bias: Vec<i32> = bias_arr
            .iter()
            .map(|v| v.as_f64().map(|n| n as i32).ok_or("bias element"))
            .collect::<Result<_, _>>()?;
        Some((img, wts, bias))
    } else {
        None
    };
    if let Some((img, wts, bias)) = explicit {
        let (wts, whash, cache) = match wts {
            Some(w) => {
                let actual = fnv1a_bytes(&w);
                if let Some(h) = claimed_hash {
                    if h != actual {
                        return Err(format!(
                            "weights_hash {h} does not match the shipped bytes (fnv1a {actual})"
                        ));
                    }
                    // Inline-with-hash: the client content-addressed
                    // this blob, so admit it into the store for every
                    // later hash-only request on any connection. An
                    // over-capacity blob is simply served uncached.
                    if let Some(store) = store {
                        let cost =
                            crate::hw::capacity::demand(&spec, crate::hw::AccumMode::I32)
                                .weight_bytes;
                        store.insert(h, Arc::new(w.clone()), cost);
                    }
                }
                let bytes = w.len() as u64;
                let cached = claimed_hash.is_some() && store.is_some();
                (w, actual, WireCache::Shipped { bytes, cached })
            }
            None => {
                let h = claimed_hash.expect("hash-only form implies a claimed hash");
                let Some(store) = store else {
                    return Err(
                        "weights_hash not negotiated (this endpoint has no weight store)"
                            .into(),
                    );
                };
                match store.get(h) {
                    Some(blob) => {
                        if blob.len() != weight_len {
                            return Err(format!(
                                "resident weights for hash {h} are {} bytes, this spec/kind needs {weight_len}",
                                blob.len()
                            ));
                        }
                        let bytes = weight_len as u64;
                        ((*blob).clone(), h, WireCache::Hit { bytes })
                    }
                    None => return Ok(Parsed::NeedWeights(h)),
                }
            }
        };
        let weights = match kind {
            JobKind::Depthwise => Tensor::from_vec(&[spec.c, 3, 3], wts),
            _ => Tensor::from_vec(&[spec.k, spec.c, 3, 3], wts),
        };
        // Explicit tensors: fingerprint the actual weight bytes (folded
        // into the FNV state as salt, so it can't alias a synthetic
        // per-spec set). Identical weights batched consecutively
        // legitimately skip the weight DMA; different weights never
        // share an id — request ids (which restart at 1 per client
        // connection) play no part, so two clients can't collide.
        let weights_id = weights_fingerprint_salted(&spec, kind, whash);
        Ok(Parsed::Job(
            Box::new(ConvJob {
                id,
                spec,
                kind,
                // The wire protocol serves production traffic only;
                // wrap-8 replies stay an in-process (experiment)
                // concern.
                accum: crate::hw::AccumMode::I32,
                img: Tensor::from_vec(&[spec.c, spec.h, spec.w], img),
                // Wire jobs own their bytes (they just crossed the
                // socket); the Arc exists for registry-path sharing.
                weights: Arc::new(weights),
                bias: Arc::new(bias),
                weights_id,
                weights_hash: whash,
                wire_weights_cached: false,
                // The propagated trace id (if any) is stamped by the
                // connection handler, which owns the negotiation state.
                trace: super::request::TraceCtx::default(),
            }),
            cache,
        ))
    } else {
        let seed = req
            .get(&["seed"])
            .and_then(Json::as_f64)
            .ok_or("need seed, img/weights/bias, or a bin frame")? as u64;
        let job = match kind {
            JobKind::Standard => ConvJob::synthetic(id, spec, seed),
            JobKind::Depthwise => ConvJob::synthetic_depthwise(id, spec, seed),
            JobKind::PointwiseAs3x3 => {
                return Err("pointwise jobs need explicit pre-lowered tensors, not a seed".into())
            }
        };
        Ok(Parsed::Job(Box::new(job), WireCache::Untracked))
    }
}

/// Render one completed job as its reply frame: the JSON header (with
/// the *client's* id restored) plus, for a binary-framed `full_output`
/// request, the i32-LE output body to write right after it.
fn render_reply(
    r: &ConvResult,
    client_id: u64,
    freq_hz: u64,
    full_output: bool,
    bin: bool,
    traced: bool,
) -> (Json, Option<Vec<u8>>) {
    if let Some(err) = &r.error {
        return (error_json(client_id, err), None);
    }
    let head: Vec<i64> = r.output.data().iter().take(8).map(|&v| v as i64).collect();
    let checksum = r
        .output
        .data()
        .iter()
        .fold(0i64, |a, &v| (a + v as i64) & 0x7FFF_FFFF);
    // Ids and checksums are exact integers on the wire: Json::uint /
    // Json::int emit the value digit-for-digit, where the old
    // `Json::num(x as f64)` silently corrupted anything above 2^53.
    let mut fields = vec![
        ("id", Json::uint(client_id)),
        ("ok", Json::Bool(true)),
        ("kind", Json::str(r.kind.tag())),
        ("core", Json::uint(r.core as u64)),
        ("backend", Json::str(r.backend)),
        ("compute_cycles", Json::uint(r.cycles.compute)),
        ("total_cycles", Json::uint(r.cycles.total)),
        (
            "sim_us",
            Json::num((r.cycles.total as f64 / freq_hz as f64 * 1e6).round()),
        ),
        ("weights_reused", Json::Bool(r.weights_reused)),
        ("output_head", Json::arr_i64(head)),
        ("checksum", Json::int(checksum)),
    ];
    if traced {
        // Traced requests get the server-side decomposition: how long
        // the job sat in this server's queue and how long its backend
        // call took, so the client can split its measured round trip
        // into wire vs remote work.
        fields.push(("queue_us", Json::uint(r.queue_us)));
        fields.push(("compute_us", Json::uint(r.compute_us)));
    }
    let mut body = None;
    if full_output {
        fields.push((
            "shape",
            Json::arr_u64(r.output.shape().iter().map(|&d| d as u64)),
        ));
        if bin {
            let bytes = encode_i32_le(r.output.data());
            fields.push(("bin_output", Json::uint(bytes.len() as u64)));
            body = Some(bytes);
        } else {
            fields.push((
                "output",
                Json::arr_i64(r.output.data().iter().map(|&v| v as i64)),
            ));
        }
    }
    (Json::obj(fields), body)
}

fn error_json(id: u64, msg: &str) -> Json {
    Json::obj(vec![
        ("id", Json::uint(id)),
        ("ok", Json::Bool(false)),
        ("error", Json::str(msg)),
    ])
}

/// The capability advertisement every connection opens with.
fn hello_json(pool: &CorePool, v2_only: bool) -> Json {
    let quotes = pool.worker_cost_models();
    let workers: Vec<Json> = pool
        .worker_capabilities()
        .iter()
        .zip(&quotes)
        .map(|((name, cap), cost)| {
            Json::obj(vec![
                ("backend", Json::str(*name)),
                ("standard", Json::Bool(cap.standard3x3)),
                ("depthwise", Json::Bool(cap.depthwise)),
                ("pointwise", Json::Bool(cap.pointwise_as_3x3)),
                (
                    "accum",
                    Json::str(match cap.accum {
                        crate::hw::AccumMode::I32 => "i32",
                        crate::hw::AccumMode::Wrap8 => "wrap8",
                    }),
                ),
                ("model", Json::str(cost.family_tag())),
                (
                    "quote",
                    Json::uint(cost.cost(&QUICKSTART, JobKind::Standard)),
                ),
            ])
        })
        .collect();
    let mut h = vec![
        (
            "proto",
            Json::uint(if v2_only { PROTO_V2 } else { PROTO_VERSION }),
        ),
        // In-revision feature flag (see "Version negotiation"):
        // this server answers `ping` control frames.
        ("ping", Json::Bool(true)),
    ];
    if !v2_only {
        // Binary tensor framing is negotiated by this flag's presence,
        // not by the proto number — a v2-only endpoint omits it and
        // clients must stay on JSON tensors.
        h.push(("bin", Json::Bool(true)));
        // Content-addressed weights (v4): this endpoint keeps a weight
        // store, so `weights_hash` requests and `need_weights` replies
        // are in play. A v2-only endpoint omits it and clients must
        // ship weights inline on every request.
        h.push(("wcache", Json::Bool(true)));
        // Trace propagation (telemetry): this endpoint accepts a
        // `trace` id on request headers and answers traced jobs with
        // server-side `queue_us`/`compute_us`. A v2-only endpoint omits
        // the flag and clients must never send the field.
        h.push(("trace", Json::Bool(true)));
    }
    h.push(("freq_hz", Json::uint(pool.ip_config().freq_hz)));
    h.push(("cores", Json::uint(pool.n_cores() as u64)));
    h.push(("workers", Json::Arr(workers)));
    Json::obj(vec![("hello", Json::obj(h))])
}

/// What the reply collector needs to render a completed job: jobs are
/// keyed by *internal* id, these restore the client-visible framing.
struct PendingMeta {
    client_id: u64,
    full_output: bool,
    bin: bool,
    psums: u64,
    /// The request carried a (negotiated) trace id: the reply echoes
    /// the server-side `queue_us`/`compute_us` decomposition.
    traced: bool,
}

/// Write one JSON line under the shared writer lock.
fn send_line(writer: &Mutex<TcpStream>, j: &Json) -> bool {
    let mut w = writer.lock().unwrap();
    writeln!(w, "{}", j.to_json()).is_ok()
}

#[allow(clippy::too_many_arguments)]
fn handle_connection(
    stream: TcpStream,
    pool: Arc<CorePool>,
    next_id: Arc<AtomicU64>,
    hello_line: Arc<String>,
    shutdown: Arc<AtomicBool>,
    down: Arc<AtomicBool>,
    admission: Option<Arc<AdmissionController>>,
    v2_only: bool,
    // The endpoint's content-addressed weight store, shared across
    // every connection (`None` on a v2-only endpoint).
    store: Option<Arc<WeightStore>>,
    // Held (not used) until this handler returns: the listener prunes
    // the chaos-kill registry by the monitor's refcount.
    _monitor: Arc<TcpStream>,
) {
    let freq = pool.ip_config().freq_hz;
    stream.set_nodelay(true).ok();
    // Readers wake periodically to poll the shutdown flag, so stop()
    // can drain handlers even while clients hold idle connections open.
    stream.set_read_timeout(Some(SHUTDOWN_POLL)).ok();
    // Bounded writes too: a client that stops reading a multi-megabyte
    // full_output reply must fail its connection, not park this handler
    // (and block stop()) on a full TCP send buffer forever.
    stream.set_write_timeout(Some(WRITE_TIMEOUT)).ok();
    // Replies are written by two threads (this reader for errors and
    // pongs, the collector for job replies), so the write half lives
    // behind a mutex; each frame (header line + optional binary body)
    // is written under one lock hold, so frames never interleave.
    let writer = match stream.try_clone() {
        Ok(w) => Arc::new(Mutex::new(w)),
        Err(_) => return,
    };
    {
        let mut w = writer.lock().unwrap();
        if writeln!(w, "{hello_line}").is_err() {
            return;
        }
    }
    // Pipelining state: jobs in flight on this connection, keyed by
    // internal id. The reader inserts before dispatch and blocks (via
    // the condvar) while the window is full; the collector removes as
    // replies complete.
    let pending: Arc<(Mutex<HashMap<u64, PendingMeta>>, Condvar)> =
        Arc::new((Mutex::new(HashMap::new()), Condvar::new()));
    // Set when a reply write fails: the socket is gone, so the
    // collector stops writing but keeps draining results (admission
    // charges must still be released).
    let conn_dead = Arc::new(AtomicBool::new(false));
    let (res_tx, res_rx) = channel::<ConvResult>();
    let collector = {
        let writer = Arc::clone(&writer);
        let pending = Arc::clone(&pending);
        let conn_dead = Arc::clone(&conn_dead);
        let admission = admission.clone();
        std::thread::Builder::new()
            .name("repro-tcp-replies".into())
            .spawn(move || {
                // Runs until every result sender is gone: the reader
                // drops the original on exit, each dispatched job's
                // clone dies with its reply.
                while let Ok(result) = res_rx.recv() {
                    let meta = {
                        let (lock, cv) = &*pending;
                        let meta = lock.lock().unwrap().remove(&result.id);
                        cv.notify_all();
                        meta
                    };
                    let Some(meta) = meta else { continue };
                    if !conn_dead.load(Ordering::Relaxed) {
                        let (header, body) = render_reply(
                            &result,
                            meta.client_id,
                            freq,
                            meta.full_output,
                            meta.bin,
                            meta.traced,
                        );
                        let mut w = writer.lock().unwrap();
                        let mut ok = writeln!(w, "{}", header.to_json()).is_ok();
                        if ok {
                            if let Some(body) = &body {
                                ok = w.write_all(body).is_ok();
                            }
                        }
                        if !ok {
                            conn_dead.store(true, Ordering::Relaxed);
                        }
                    }
                    // Release the admission charge even on a dead
                    // connection — in-flight budget tracks compute,
                    // not sockets.
                    if let Some(ac) = &admission {
                        ac.complete(meta.psums);
                    }
                }
            })
            .expect("spawn reply collector")
    };
    let mut reader = BufReader::new(stream);
    let mut buf: Vec<u8> = Vec::new();
    'conn: loop {
        if shutdown.load(Ordering::Relaxed)
            || down.load(Ordering::Relaxed)
            || conn_dead.load(Ordering::Relaxed)
        {
            break;
        }
        match read_line_capped(&mut reader, &mut buf, MAX_LINE_BYTES) {
            Ok(LineRead::Eof) => break, // client closed the connection
            Ok(LineRead::Line) => {
                let line = String::from_utf8_lossy(&buf).into_owned();
                buf.clear();
                let trimmed = line.trim();
                if trimmed.is_empty() {
                    continue;
                }
                let req = match Json::parse(trimmed) {
                    Err(e) => {
                        // No id to echo and, if a binary body followed
                        // this garbage, no way to resync — answer and
                        // keep line-reading; a desynced stream fails
                        // the over-cap guard soon after.
                        if !send_line(&writer, &error_json(0, &format!("bad json: {e}"))) {
                            break 'conn;
                        }
                        continue;
                    }
                    Ok(req) => req,
                };
                // Ping control frame: answered before job parsing and
                // before admission — a health probe must stay cheap, is
                // never shed, and jumps the reply pipeline.
                if let Some(seq) = req.get(&["ping"]).and_then(Json::as_f64) {
                    if !send_line(&writer, &Json::obj(vec![("pong", Json::num(seq))])) {
                        break 'conn;
                    }
                    continue;
                }
                let internal = next_id.fetch_add(1, Ordering::Relaxed);
                let client_id = req.get(&["id"]).and_then(Json::as_u64).unwrap_or(internal);
                // Binary bodies must be consumed *before* any error
                // path that keeps the connection, or the stream
                // desyncs.
                let bin: Option<BinTensors> = match parse_bin_lens(&req) {
                    Err(e) => {
                        // Unusable declaration: the server cannot know
                        // how many bytes follow. Error, then sever.
                        let _ = send_line(&writer, &error_json(client_id, &e));
                        break 'conn;
                    }
                    Ok(None) => None,
                    Ok(Some([li, lw, lb])) => {
                        if li.saturating_add(lw).saturating_add(lb) > MAX_BIN_BYTES {
                            let _ = send_line(
                                &writer,
                                &error_json(
                                    client_id,
                                    &format!(
                                        "bin frame {} bytes exceeds cap {MAX_BIN_BYTES}",
                                        li as u128 + lw as u128 + lb as u128
                                    ),
                                ),
                            );
                            break 'conn;
                        }
                        let mut bt = BinTensors {
                            img: vec![0u8; li],
                            weights: vec![0u8; lw],
                            bias: vec![0u8; lb],
                        };
                        for body in [&mut bt.img, &mut bt.weights, &mut bt.bias] {
                            if read_exact_polled(&mut reader, body, &shutdown, &down).is_err() {
                                break 'conn;
                            }
                        }
                        Some(bt)
                    }
                };
                if v2_only && bin.is_some() {
                    // Bytes are consumed, the stream is in sync: a
                    // clean per-job error, not a disconnect.
                    if !send_line(
                        &writer,
                        &error_json(
                            client_id,
                            "binary framing not negotiated (this endpoint is wire v2)",
                        ),
                    ) {
                        break 'conn;
                    }
                    continue;
                }
                let is_bin = bin.is_some();
                let full_output = req
                    .get(&["full_output"])
                    .and_then(Json::as_bool)
                    .unwrap_or(false);
                // Trace propagation is feature-negotiated via the hello
                // (never advertised by a v2-only endpoint): a legacy
                // endpoint ignores a stray trace field entirely — no
                // spans, no timing in the reply.
                let trace_id = if v2_only {
                    0
                } else {
                    req.get(&["trace"]).and_then(Json::as_u64).unwrap_or(0)
                };
                let mut job = match job_from_request(internal, &req, bin, store.as_deref()) {
                    Err(e) => {
                        if !send_line(&writer, &error_json(client_id, &e)) {
                            break 'conn;
                        }
                        continue;
                    }
                    Ok(Parsed::NeedWeights(h)) => {
                        // Fast miss: tell the client to re-send this
                        // request once with the blob inline. Answered
                        // before admission — a miss must not burn a
                        // queue slot.
                        pool.metrics.record_weight_miss();
                        let frame = Json::obj(vec![
                            ("id", Json::uint(client_id)),
                            ("ok", Json::Bool(false)),
                            ("need_weights", Json::Bool(true)),
                            ("weights_hash", Json::uint(h)),
                            (
                                "error",
                                Json::str(&format!(
                                    "weights {h} not resident; re-send inline with weights_hash"
                                )),
                            ),
                        ]);
                        if !send_line(&writer, &frame) {
                            break 'conn;
                        }
                        continue;
                    }
                    Ok(Parsed::Job(job, cache)) => {
                        match cache {
                            WireCache::Untracked => {}
                            WireCache::Shipped { bytes, .. } => {
                                pool.metrics.record_wire_weight_bytes(bytes);
                            }
                            WireCache::Hit { bytes } => {
                                pool.metrics.record_weight_hit(bytes);
                            }
                        }
                        *job
                    }
                };
                // Stamp the propagated trace id so a server running its
                // own span sink records this hop under the *client's*
                // trace. The layer marker keeps the dispatcher from
                // minting a second request root — the root lives on the
                // originating front.
                if trace_id != 0 {
                    job.trace.id = trace_id;
                    job.trace.layer = Some(0);
                }
                // Admission control gates on the job's PSUM quote (the
                // unit the dispatcher balances by) with the fast-reject
                // serving policy: an over-budget request gets a
                // `rejected` frame now, not a queue slot.
                let psums = job.psums();
                if let Some(ac) = &admission {
                    if ac.admit(psums, Policy::Reject) == Admission::Rejected {
                        pool.metrics.record_shed();
                        let msg = format!(
                            "admission: {psums} PSUMs would exceed the in-flight budget ({}/{} in flight)",
                            ac.inflight(),
                            ac.capacity()
                        );
                        let frame = Json::obj(vec![
                            ("id", Json::uint(client_id)),
                            ("ok", Json::Bool(false)),
                            ("rejected", Json::Bool(true)),
                            ("error", Json::str(&msg)),
                        ]);
                        if !send_line(&writer, &frame) {
                            break 'conn;
                        }
                        continue;
                    }
                }
                // Pipelining window: park the reader (socket unread ->
                // TCP backpressure) while the connection is full,
                // without blocking shutdown.
                {
                    let (lock, cv) = &*pending;
                    let mut map = lock.lock().unwrap();
                    while map.len() >= MAX_CONN_INFLIGHT {
                        if shutdown.load(Ordering::Relaxed)
                            || down.load(Ordering::Relaxed)
                            || conn_dead.load(Ordering::Relaxed)
                        {
                            drop(map);
                            if let Some(ac) = &admission {
                                ac.complete(psums);
                            }
                            break 'conn;
                        }
                        let (m, _timeout) = cv.wait_timeout(map, SHUTDOWN_POLL).unwrap();
                        map = m;
                    }
                    map.insert(
                        internal,
                        PendingMeta {
                            client_id,
                            full_output,
                            bin: is_bin,
                            psums,
                            traced: trace_id != 0,
                        },
                    );
                }
                let batch = super::batcher::Batch {
                    spec: job.spec,
                    weights_id: job.weights_id,
                    kind: job.kind,
                    accum: job.accum,
                    jobs: vec![Submission {
                        job,
                        reply: res_tx.clone(),
                        enqueued: std::time::Instant::now(),
                    }],
                };
                // An unroutable job (e.g. depthwise against a
                // standard-only pool) is a client error on the wire,
                // not a deployment panic.
                if let Err(back) = pool.try_dispatch(batch) {
                    {
                        let (lock, cv) = &*pending;
                        lock.lock().unwrap().remove(&internal);
                        cv.notify_all();
                    }
                    if let Some(ac) = &admission {
                        ac.complete(psums);
                    }
                    let msg = format!(
                        "no backend in this pool serves {:?} jobs in {:?} accum mode",
                        back.kind, back.accum
                    );
                    if !send_line(&writer, &error_json(client_id, &msg)) {
                        break 'conn;
                    }
                }
            }
            // Read timeout: loop to re-check shutdown. Partial-line
            // bytes stay accumulated in `buf`, so mid-line timeouts
            // lose nothing.
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                continue;
            }
            // Includes an over-cap frame: drop the connection.
            Err(_) => break,
        }
    }
    // Hand the channel to the in-flight jobs alone; once their replies
    // (or drops) land, the collector's recv() disconnects and it exits
    // — every dispatched job is answered (or its admission charge
    // released) before this handler is considered drained.
    drop(res_tx);
    let _ = collector.join();
}

impl TcpServer {
    /// Bind and serve on `addr` (use port 0 for an ephemeral port). The
    /// pool is whatever the config describes — simulated IP cores,
    /// golden / im2col host workers, even this peer's own remote peers.
    pub fn start(addr: &str, config: CoordinatorConfig) -> anyhow::Result<Self> {
        let listener = Arc::new(TcpListener::bind(addr)?);
        let local = listener.local_addr()?;
        let v2_only = config.wire_v2_only;
        // The weight store is sized like the board: the BRAM36 budget
        // (full XC7Z020 inventory unless the config pins it) prices
        // each blob at its §4.2 on-chip footprint, so residency means
        // "would fit the accelerator's weight BRAMs", not "fits RAM".
        let store = (!v2_only).then(|| {
            Arc::new(WeightStore::with_bram36_blocks(
                config
                    .weight_store_bram36
                    .unwrap_or(crate::hw::device::XC7Z020_CLG400.bram36),
            ))
        });
        let pool = Arc::new(super::server::build_pool(&config)?);
        let admission = config
            .max_inflight_psums
            .map(|m| Arc::new(AdmissionController::new(m)));
        let hello_line = Arc::new(hello_json(&pool, v2_only).to_json());
        let next_id = Arc::new(AtomicU64::new(1));
        let shutdown = Arc::new(AtomicBool::new(false));
        let down = Arc::new(AtomicBool::new(false));
        let conns: Arc<Mutex<Vec<std::thread::JoinHandle<()>>>> =
            Arc::new(Mutex::new(Vec::new()));
        let live: Arc<Mutex<Vec<Arc<TcpStream>>>> = Arc::new(Mutex::new(Vec::new()));
        let shutdown_flag = Arc::clone(&shutdown);
        let down_flag = Arc::clone(&down);
        let conns_in_listener = Arc::clone(&conns);
        let live_in_listener = Arc::clone(&live);
        let pool_in_listener = Arc::clone(&pool);
        let admission_in_listener = admission.clone();
        let store_in_listener = store.clone();
        let listener_in_thread = Arc::clone(&listener);
        // Event-driven accept: the loop *blocks* in accept() — no poll
        // sleep, no idle wakeups. stop() wakes it with a throwaway
        // connection after flipping the listener non-blocking (the
        // flip alone covers the case where that connect is refused).
        let listener_thread = std::thread::Builder::new()
            .name("repro-tcp".into())
            .spawn(move || {
                loop {
                    match listener_in_thread.accept() {
                        Ok((stream, _)) => {
                            // The stop() wake-up connection lands here.
                            if shutdown_flag.load(Ordering::Relaxed) {
                                break;
                            }
                            // Chaos: a "dead" peer accepts nothing. The
                            // socket closes without a hello, which a
                            // dialing client reads as connection refused.
                            if down_flag.load(Ordering::Relaxed) {
                                drop(stream);
                                continue;
                            }
                            stream.set_nonblocking(false).ok();
                            let monitor = match stream.try_clone() {
                                Ok(m) => Arc::new(m),
                                Err(_) => continue,
                            };
                            // Register the monitor before the handler
                            // can greet: once a client sees the hello,
                            // set_down is guaranteed to find (and can
                            // sever) this connection.
                            {
                                let mut live = live_in_listener.lock().unwrap();
                                live.retain(|s| Arc::strong_count(s) > 1);
                                live.push(Arc::clone(&monitor));
                            }
                            let pool = Arc::clone(&pool_in_listener);
                            let next_id = Arc::clone(&next_id);
                            let hello = Arc::clone(&hello_line);
                            let shutdown = Arc::clone(&shutdown_flag);
                            let down = Arc::clone(&down_flag);
                            let admission = admission_in_listener.clone();
                            let store = store_in_listener.clone();
                            let handle = std::thread::spawn(move || {
                                handle_connection(
                                    stream, pool, next_id, hello, shutdown, down, admission,
                                    v2_only, store, monitor,
                                )
                            });
                            let mut conns = conns_in_listener.lock().unwrap();
                            // Reap finished handlers so long-lived
                            // servers don't accumulate dead handles.
                            conns.retain(|h| !h.is_finished());
                            conns.push(handle);
                        }
                        // Only reachable after stop() flipped the
                        // listener non-blocking; the short sleep guards
                        // against a hot spin if a platform surfaces
                        // spurious WouldBlock before shutdown is set.
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            if shutdown_flag.load(Ordering::Relaxed) {
                                break;
                            }
                            std::thread::sleep(Duration::from_millis(5));
                        }
                        Err(_) => {
                            if shutdown_flag.load(Ordering::Relaxed) {
                                break;
                            }
                            std::thread::sleep(Duration::from_millis(10));
                        }
                    }
                }
            })?;
        Ok(TcpServer {
            addr: local,
            listener,
            listener_thread,
            shutdown,
            down,
            conns,
            live,
            admission,
            v2_only,
            store,
            pool,
        })
    }

    /// The capability line every connection is greeted with (tests and
    /// observability).
    pub fn hello(&self) -> Json {
        hello_json(&self.pool, self.v2_only)
    }

    /// This server's serving metrics (chaos harnesses and tests assert
    /// per-peer completion/shed counts through this).
    pub fn metrics(&self) -> Arc<super::metrics::Metrics> {
        Arc::clone(&self.pool.metrics)
    }

    /// The admission controller, when the config set an in-flight PSUM
    /// budget (tests pre-load it to exercise shedding deterministically).
    pub fn admission(&self) -> Option<Arc<AdmissionController>> {
        self.admission.clone()
    }

    /// The endpoint's content-addressed weight store (`None` on a
    /// v2-only endpoint). Tests inspect residency and eviction order
    /// through this.
    pub fn weight_store(&self) -> Option<Arc<WeightStore>> {
        self.store.clone()
    }

    /// Chaos hook: simulate this peer crashing (`down = true`) and
    /// coming back (`down = false`) without releasing the port. While
    /// down, every live connection is severed mid-stream and the accept
    /// loop drops new connections before the hello — exactly what a
    /// dialing client sees from a crashed process. Reviving restores
    /// service for *new* connections; severed ones stay dead (clients
    /// must redial, as they would after a real crash).
    pub fn set_down(&self, down: bool) {
        self.down.store(down, Ordering::Relaxed);
        if down {
            let live = self.live.lock().unwrap();
            for s in live.iter() {
                let _ = s.shutdown(std::net::Shutdown::Both);
            }
        }
    }

    /// Stop accepting, drain every connection handler (in-flight
    /// requests are answered first), then shut the pool down.
    pub fn stop(self) {
        self.shutdown.store(true, Ordering::Relaxed);
        // Unwedge any submitter parked on the admission Condvar before
        // joining handlers — a stopping server must not hang on its own
        // backpressure.
        if let Some(ac) = &self.admission {
            ac.shutdown();
        }
        // Wake the blocking accept(): flip the listener non-blocking
        // (any racing accept now returns WouldBlock and sees the flag)
        // and nudge it with a throwaway connection in case it was
        // already parked in the kernel.
        self.listener.set_nonblocking(true).ok();
        let mut wake = self.addr;
        if wake.ip().is_unspecified() {
            wake.set_ip(match wake.ip() {
                std::net::IpAddr::V4(_) => std::net::IpAddr::V4(std::net::Ipv4Addr::LOCALHOST),
                std::net::IpAddr::V6(_) => std::net::IpAddr::V6(std::net::Ipv6Addr::LOCALHOST),
            });
        }
        let _ = TcpStream::connect_timeout(&wake, Duration::from_secs(1));
        let _ = self.listener_thread.join();
        loop {
            let handle = self.conns.lock().unwrap().pop();
            match handle {
                Some(h) => {
                    let _ = h.join();
                }
                None => break,
            }
        }
        // All other Arc holders have exited; shut the workers down
        // cleanly rather than leaking them to process teardown.
        if let Ok(pool) = Arc::try_unwrap(self.pool) {
            pool.shutdown();
        }
    }
}

/// Blocking one-shot client (used by tests, examples and load
/// generators): connect, swallow the `hello` greeting, send one
/// request, return its reply. Speaks JSON tensors regardless of what
/// the hello advertises — the v2-compatible lowest common denominator.
pub fn request_once(addr: &std::net::SocketAddr, body: &Json) -> anyhow::Result<Json> {
    let mut stream = TcpStream::connect(addr)?;
    stream.set_nodelay(true).ok();
    writeln!(stream, "{}", body.to_json())?;
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    reader.read_line(&mut line)?; // hello frame
    let hello = Json::parse(&line).map_err(|e| anyhow::anyhow!("bad hello: {e}"))?;
    anyhow::ensure!(
        hello.get(&["hello"]).is_some(),
        "server did not open with a hello frame"
    );
    line.clear();
    reader.read_line(&mut line)?;
    Json::parse(&line).map_err(|e| anyhow::anyhow!("bad response: {e}"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::depthwise::golden_depthwise3x3;
    use crate::model::{golden, QUICKSTART};
    use crate::util::prng::Prng;

    fn start_n(cores: usize) -> TcpServer {
        TcpServer::start(
            "127.0.0.1:0",
            CoordinatorConfig::default().with_cores(cores),
        )
        .expect("bind")
    }

    fn start() -> TcpServer {
        start_n(2)
    }

    /// Unwrap a parse outcome into its job (for tests that expect a
    /// dispatchable request, not a `need_weights` answer).
    fn expect_job(p: Result<Parsed, String>) -> ConvJob {
        match p.unwrap() {
            Parsed::Job(job, _) => *job,
            Parsed::NeedWeights(h) => panic!("unexpected need_weights for {h}"),
        }
    }

    /// Raw client helper: connect, return (hello frame, stream, reader).
    fn connect_raw(
        addr: std::net::SocketAddr,
    ) -> (Json, TcpStream, BufReader<TcpStream>) {
        let stream = TcpStream::connect(addr).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        (Json::parse(&line).unwrap(), stream, reader)
    }

    /// Read one reply frame: the JSON header line plus, when it
    /// declares `bin_output`, the decoded i32 body that follows it.
    fn read_reply_frame(reader: &mut BufReader<TcpStream>) -> (Json, Option<Vec<i32>>) {
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let header = Json::parse(&line).unwrap_or_else(|e| panic!("bad header {line:?}: {e}"));
        let body = header
            .get(&["bin_output"])
            .and_then(Json::as_usize)
            .map(|n| {
                let mut buf = vec![0u8; n];
                reader.read_exact(&mut buf).unwrap();
                decode_i32_le(&buf)
            });
        (header, body)
    }

    #[test]
    fn handshake_advertises_pool_capability() {
        let server = TcpServer::start(
            "127.0.0.1:0",
            CoordinatorConfig::default()
                .with_cores(1)
                .with_im2col_workers(1),
        )
        .unwrap();
        let (hello, _stream, _reader) = connect_raw(server.addr);
        let h = hello.get(&["hello"]).expect("hello frame");
        assert_eq!(h.get(&["proto"]).unwrap().as_usize(), Some(4));
        // In-revision feature flags: pings answered, binary framing,
        // content-addressed weights and trace propagation on.
        assert_eq!(h.get(&["ping"]).unwrap().as_bool(), Some(true));
        assert_eq!(h.get(&["bin"]).unwrap().as_bool(), Some(true));
        assert_eq!(h.get(&["wcache"]).unwrap().as_bool(), Some(true));
        assert_eq!(h.get(&["trace"]).unwrap().as_bool(), Some(true));
        assert_eq!(h.get(&["cores"]).unwrap().as_usize(), Some(2));
        assert!(h.get(&["freq_hz"]).unwrap().as_f64().unwrap() > 0.0);
        let workers = h.get(&["workers"]).unwrap().as_arr().unwrap();
        assert_eq!(workers.len(), 2);
        let names: Vec<&str> = workers
            .iter()
            .map(|w| w.get(&["backend"]).unwrap().as_str().unwrap())
            .collect();
        assert_eq!(names, vec!["sim-ipcore-i32", "im2col-cpu"]);
        let models: Vec<&str> = workers
            .iter()
            .map(|w| w.get(&["model"]).unwrap().as_str().unwrap())
            .collect();
        assert_eq!(models, vec!["sim-cycles", "im2col"]);
        for w in workers {
            assert_eq!(w.get(&["accum"]).unwrap().as_str(), Some("i32"));
            assert_eq!(w.get(&["depthwise"]).unwrap().as_bool(), Some(true));
            assert!(w.get(&["quote"]).unwrap().as_f64().unwrap() >= 1.0);
        }
        server.stop();
    }

    #[test]
    fn seed_request_round_trips() {
        let server = start();
        let req = Json::parse(
            r#"{"id":7,"spec":{"c":8,"h":16,"w":16,"k":8},"seed":42}"#,
        )
        .unwrap();
        let resp = request_once(&server.addr, &req).unwrap();
        assert_eq!(resp.get(&["ok"]).unwrap().as_bool(), Some(true));
        assert_eq!(resp.get(&["id"]).unwrap().as_usize(), Some(7));
        assert_eq!(resp.get(&["kind"]).unwrap().as_str(), Some("standard"));
        assert_eq!(
            resp.get(&["compute_cycles"]).unwrap().as_usize(),
            Some(6272)
        );
        // No full output unless asked for.
        assert!(resp.get(&["output"]).is_none());
        assert!(resp.get(&["bin_output"]).is_none());
        // Checksum matches a local recomputation of the same seed.
        let job = ConvJob::synthetic(7, QUICKSTART, 42);
        let want = golden::conv3x3_i32(&job.img, &job.weights, &job.bias, false);
        let checksum = want
            .data()
            .iter()
            .fold(0i64, |a, &v| (a + v as i64) & 0x7FFF_FFFF);
        assert_eq!(
            resp.get(&["checksum"]).unwrap().as_f64(),
            Some(checksum as f64)
        );
        server.stop();
    }

    #[test]
    fn explicit_tensor_request_computes() {
        let server = start();
        // 1-channel 4x4 image, 4 kernels: small enough to inline.
        let img: Vec<u64> = (0..16).collect();
        let wts: Vec<u64> = (0..36).map(|i| i % 5).collect();
        let req = Json::obj(vec![
            ("id", Json::num(1u32)),
            (
                "spec",
                Json::obj(vec![
                    ("c", Json::num(1u32)),
                    ("h", Json::num(4u32)),
                    ("w", Json::num(4u32)),
                    ("k", Json::num(4u32)),
                ]),
            ),
            ("img", Json::arr_u64(img.clone())),
            ("weights", Json::arr_u64(wts.clone())),
            ("bias", Json::arr_i64([0, 0, 0, 0])),
        ]);
        let resp = request_once(&server.addr, &req).unwrap();
        assert_eq!(resp.get(&["ok"]).unwrap().as_bool(), Some(true), "{resp:?}");
        // Verify output head against golden.
        let img_t = Tensor::from_vec(&[1, 4, 4], img.iter().map(|&v| v as u8).collect());
        let wts_t = Tensor::from_vec(&[4, 1, 3, 3], wts.iter().map(|&v| v as u8).collect());
        let want = golden::conv3x3_i32(&img_t, &wts_t, &[0; 4], false);
        let head = resp.get(&["output_head"]).unwrap().as_arr().unwrap();
        for (a, b) in head.iter().zip(want.data()) {
            assert_eq!(a.as_f64().unwrap() as i32, *b);
        }
        server.stop();
    }

    #[test]
    fn full_output_round_trips_the_whole_tensor() {
        let server = start();
        let spec = LayerSpec::new(2, 5, 5, 4);
        let mut rng = Prng::new(91);
        let img = rng.bytes_below(spec.c * spec.h * spec.w, 256);
        let wts = rng.bytes_below(spec.k * spec.c * 9, 256);
        let bias: Vec<i64> = (0..spec.k).map(|_| rng.range_i64(-20, 20)).collect();
        let req = Json::obj(vec![
            ("id", Json::num(5u32)),
            (
                "spec",
                Json::obj(vec![
                    ("c", Json::num(2u32)),
                    ("h", Json::num(5u32)),
                    ("w", Json::num(5u32)),
                    ("k", Json::num(4u32)),
                ]),
            ),
            ("img", Json::arr_u64(img.iter().map(|&v| v as u64))),
            ("weights", Json::arr_u64(wts.iter().map(|&v| v as u64))),
            ("bias", Json::arr_i64(bias.clone())),
            ("full_output", Json::Bool(true)),
        ]);
        let resp = request_once(&server.addr, &req).unwrap();
        assert_eq!(resp.get(&["ok"]).unwrap().as_bool(), Some(true), "{resp:?}");
        let shape: Vec<usize> = resp
            .get(&["shape"])
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|v| v.as_usize().unwrap())
            .collect();
        assert_eq!(shape, vec![4, 3, 3]);
        let got: Vec<i32> = resp
            .get(&["output"])
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|v| v.as_f64().unwrap() as i32)
            .collect();
        let img_t = Tensor::from_vec(&[2, 5, 5], img);
        let wts_t = Tensor::from_vec(&[4, 2, 3, 3], wts);
        let bias_i32: Vec<i32> = bias.iter().map(|&b| b as i32).collect();
        let want = golden::conv3x3_i32(&img_t, &wts_t, &bias_i32, false);
        assert_eq!(got, want.data(), "full tensor must survive the wire");
        server.stop();
    }

    #[test]
    fn depthwise_over_the_wire_matches_golden() {
        let server = start();
        let c = 8usize;
        let (h, w) = (10usize, 10usize);
        let mut rng = Prng::new(92);
        let img = rng.bytes_below(c * h * w, 256);
        let wts = rng.bytes_below(c * 9, 256);
        let bias: Vec<i64> = (0..c).map(|_| rng.range_i64(-100, 100)).collect();
        let req = Json::obj(vec![
            ("id", Json::num(6u32)),
            ("kind", Json::str("depthwise")),
            (
                "spec",
                Json::obj(vec![
                    ("c", Json::num(c as u32)),
                    ("h", Json::num(h as u32)),
                    ("w", Json::num(w as u32)),
                    ("k", Json::num(c as u32)),
                    ("relu", Json::Bool(true)),
                ]),
            ),
            ("img", Json::arr_u64(img.iter().map(|&v| v as u64))),
            ("weights", Json::arr_u64(wts.iter().map(|&v| v as u64))),
            ("bias", Json::arr_i64(bias.clone())),
            ("full_output", Json::Bool(true)),
        ]);
        let resp = request_once(&server.addr, &req).unwrap();
        assert_eq!(resp.get(&["ok"]).unwrap().as_bool(), Some(true), "{resp:?}");
        assert_eq!(resp.get(&["kind"]).unwrap().as_str(), Some("depthwise"));
        let got: Vec<i32> = resp
            .get(&["output"])
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|v| v.as_f64().unwrap() as i32)
            .collect();
        let img_t = Tensor::from_vec(&[c, h, w], img);
        let wts_t = Tensor::from_vec(&[c, 3, 3], wts);
        let bias_i32: Vec<i32> = bias.iter().map(|&b| b as i32).collect();
        let want = golden_depthwise3x3(&img_t, &wts_t, &bias_i32, true);
        assert_eq!(got, want.data(), "depthwise+relu must survive the wire");
        server.stop();
    }

    #[test]
    fn synthetic_depthwise_seed_request_works() {
        let server = start();
        let req = Json::parse(
            r#"{"id":8,"kind":"depthwise","spec":{"c":8,"h":10,"w":10,"k":8},"seed":3}"#,
        )
        .unwrap();
        let resp = request_once(&server.addr, &req).unwrap();
        assert_eq!(resp.get(&["ok"]).unwrap().as_bool(), Some(true), "{resp:?}");
        let job = ConvJob::synthetic_depthwise(8, LayerSpec::new(8, 10, 10, 8), 3);
        let want = golden_depthwise3x3(&job.img, &job.weights, &job.bias, false);
        let checksum = want
            .data()
            .iter()
            .fold(0i64, |a, &v| (a + v as i64) & 0x7FFF_FFFF);
        assert_eq!(
            resp.get(&["checksum"]).unwrap().as_f64(),
            Some(checksum as f64)
        );
        server.stop();
    }

    #[test]
    fn explicit_weight_sets_fingerprint_by_bytes_not_request_id() {
        // Request ids restart at 1 per client connection, so they must
        // play no part in the weight fingerprint: same weight bytes
        // share an id (legitimate DMA reuse), different bytes never do.
        let req = |id: u64, w0: u64| {
            Json::obj(vec![
                ("id", Json::num(id as f64)),
                (
                    "spec",
                    Json::obj(vec![
                        ("c", Json::num(1u32)),
                        ("h", Json::num(4u32)),
                        ("w", Json::num(4u32)),
                        ("k", Json::num(4u32)),
                    ]),
                ),
                ("img", Json::arr_u64(vec![0u64; 16])),
                (
                    "weights",
                    Json::arr_u64((0..36u64).map(|i| if i == 0 { w0 } else { 1 })),
                ),
                ("bias", Json::arr_i64([0, 0, 0, 0])),
            ])
        };
        let a = expect_job(job_from_request(1, &req(1, 5), None, None));
        let b = expect_job(job_from_request(2, &req(2, 5), None, None));
        let c = expect_job(job_from_request(3, &req(3, 6), None, None));
        assert_eq!(a.weights_id, b.weights_id, "same bytes, different request ids");
        assert_ne!(a.weights_id, c.weights_id, "different bytes must never alias");
        // The pure byte address travels on the job too (v4 residency
        // snapshots key off it), distinct from the salted weights_id.
        assert_eq!(a.weights_hash, b.weights_hash);
        assert_ne!(a.weights_hash, c.weights_hash);
        assert!(!a.wire_weights_cached, "the wire parser never pre-marks residency");
    }

    #[test]
    fn binary_and_json_explicit_requests_build_identical_jobs() {
        // The two encodings of the same tensors must produce the same
        // job — same weights fingerprint, same data — so batching and
        // DMA-reuse behave identically whichever framing a client uses.
        let spec = LayerSpec::new(1, 4, 4, 4);
        let img: Vec<u8> = (0..16).collect();
        let wts: Vec<u8> = (0..36).map(|i| i % 5).collect();
        let bias = [3i32, -1, 0, 7];
        let json_req = Json::obj(vec![
            ("id", Json::num(1u32)),
            (
                "spec",
                Json::obj(vec![
                    ("c", Json::num(1u32)),
                    ("h", Json::num(4u32)),
                    ("w", Json::num(4u32)),
                    ("k", Json::num(4u32)),
                ]),
            ),
            ("img", Json::arr_u64(img.iter().map(|&v| v as u64))),
            ("weights", Json::arr_u64(wts.iter().map(|&v| v as u64))),
            ("bias", Json::arr_i64(bias.iter().map(|&b| b as i64))),
        ]);
        let a = expect_job(job_from_request(1, &json_req, None, None));
        // Binary path: header parsed from the shared encoder's frame.
        let frame = encode_request_frame(
            1,
            JobKind::Standard,
            &spec,
            &img,
            &wts,
            &bias,
            false,
            true,
        );
        let nl = frame.iter().position(|&b| b == b'\n').unwrap();
        let header = Json::parse(std::str::from_utf8(&frame[..nl]).unwrap()).unwrap();
        let lens = parse_bin_lens(&header).unwrap().unwrap();
        assert_eq!(lens, [16, 36, 16]);
        let b = expect_job(job_from_request(
            1,
            &header,
            Some(BinTensors {
                img: img.clone(),
                weights: wts.clone(),
                bias: encode_i32_le(&bias),
            }),
            None,
        ));
        assert_eq!(a.weights_id, b.weights_id);
        assert_eq!(a.img.data(), b.img.data());
        assert_eq!(a.weights.data(), b.weights.data());
        assert_eq!(a.bias, b.bias);
    }

    #[test]
    fn bad_requests_get_errors_not_disconnects() {
        let server = start();
        for bad in [
            "not json at all",
            r#"{"id":1}"#,
            r#"{"id":2,"spec":{"c":4,"h":8,"w":8,"k":6},"seed":1}"#, // K%4
            r#"{"id":3,"spec":{"c":1,"h":4,"w":4,"k":4},"img":[1,2,3]}"#, // short
            r#"{"id":4,"kind":"depthwise","spec":{"c":4,"h":8,"w":8,"k":8},"seed":1}"#, // K != C
            r#"{"id":5,"kind":"pointwise","spec":{"c":4,"h":8,"w":8,"k":4},"seed":1}"#, // no synth
            r#"{"id":6,"kind":"transposed","spec":{"c":4,"h":8,"w":8,"k":4},"seed":1}"#,
        ] {
            let mut stream = TcpStream::connect(server.addr).unwrap();
            let mut reader = BufReader::new(stream.try_clone().unwrap());
            let mut line = String::new();
            reader.read_line(&mut line).unwrap(); // hello
            writeln!(stream, "{bad}").unwrap();
            line.clear();
            reader.read_line(&mut line).unwrap();
            let resp = Json::parse(&line).unwrap();
            assert_eq!(resp.get(&["ok"]).unwrap().as_bool(), Some(false), "{bad}");
            assert!(resp.get(&["error"]).is_some());
        }
        server.stop();
    }

    #[test]
    fn pipelined_burst_answers_every_request() {
        // Eight back-to-back requests written before a single reply is
        // read: the server dispatches them all (pipelining), replies
        // arrive id-matched in *some* order, none are lost.
        let server = start();
        let (_hello, mut stream, mut reader) = connect_raw(server.addr);
        for i in 0..8 {
            writeln!(
                stream,
                r#"{{"id":{i},"spec":{{"c":4,"h":8,"w":8,"k":4}},"seed":{i}}}"#
            )
            .unwrap();
        }
        let mut seen = Vec::new();
        for _ in 0..8 {
            let (resp, _body) = read_reply_frame(&mut reader);
            assert_eq!(resp.get(&["ok"]).unwrap().as_bool(), Some(true), "{resp:?}");
            seen.push(resp.get(&["id"]).unwrap().as_usize().unwrap());
        }
        seen.sort();
        assert_eq!(seen, (0..8).collect::<Vec<_>>());
        drop(stream);
        server.stop();
    }

    #[test]
    fn ping_round_trips_a_pong() {
        let server = start_n(1);
        let (_hello, mut stream, mut reader) = connect_raw(server.addr);
        writeln!(stream, r#"{{"ping":7}}"#).unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let resp = Json::parse(&line).unwrap();
        assert_eq!(resp.get(&["pong"]).unwrap().as_usize(), Some(7));
        assert!(resp.get(&["id"]).is_none(), "pongs carry no id");
        // The connection still serves normal requests afterwards.
        writeln!(stream, r#"{{"id":1,"spec":{{"c":4,"h":8,"w":8,"k":4}},"seed":1}}"#).unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        let resp = Json::parse(&line).unwrap();
        assert_eq!(resp.get(&["ok"]).unwrap().as_bool(), Some(true));
        server.stop();
    }

    #[test]
    fn over_budget_request_gets_fast_rejected_frame() {
        let server = TcpServer::start(
            "127.0.0.1:0",
            CoordinatorConfig {
                max_inflight_psums: Some(100),
                ..CoordinatorConfig::default().with_cores(1)
            },
        )
        .unwrap();
        let ac = server.admission().expect("budgeted server has a controller");
        // Deterministically saturate the budget, as concurrent in-flight
        // work would.
        use crate::coordinator::backpressure::{Admission, Policy};
        assert_eq!(ac.admit(100, Policy::Reject), Admission::Admitted);
        let req = Json::parse(r#"{"id":3,"spec":{"c":4,"h":8,"w":8,"k":4},"seed":1}"#).unwrap();
        let t0 = std::time::Instant::now();
        let resp = request_once(&server.addr, &req).unwrap();
        assert!(
            t0.elapsed() < Duration::from_secs(5),
            "rejection must be fast, not queued"
        );
        assert_eq!(resp.get(&["ok"]).unwrap().as_bool(), Some(false), "{resp:?}");
        assert_eq!(resp.get(&["rejected"]).unwrap().as_bool(), Some(true));
        assert_eq!(resp.get(&["id"]).unwrap().as_usize(), Some(3));
        assert!(resp
            .get(&["error"])
            .unwrap()
            .as_str()
            .unwrap()
            .starts_with("admission:"));
        assert_eq!(server.metrics().shed.load(Ordering::Relaxed), 1);
        // Budget frees -> the same request is served.
        ac.complete(100);
        let resp = request_once(&server.addr, &req).unwrap();
        assert_eq!(resp.get(&["ok"]).unwrap().as_bool(), Some(true), "{resp:?}");
        assert_eq!(ac.inflight(), 0, "served request released its charge");
        server.stop();
    }

    #[test]
    fn set_down_severs_connections_and_revive_restores_service() {
        let server = start_n(1);
        let (_hello, _stream, mut reader) = connect_raw(server.addr);
        server.set_down(true);
        // The live connection is severed mid-stream: the client reads
        // EOF (or a reset), never a reply.
        let mut line = String::new();
        let n = reader.read_line(&mut line).unwrap_or(0);
        assert_eq!(n, 0, "severed connection must not produce data: {line:?}");
        // New connections are dropped before the hello greeting.
        let s2 = TcpStream::connect(server.addr).unwrap();
        let mut r2 = BufReader::new(s2);
        let mut l2 = String::new();
        let n2 = r2.read_line(&mut l2).unwrap_or(0);
        assert_eq!(n2, 0, "a down server must not greet: {l2:?}");
        // Revive: fresh connections are served again.
        server.set_down(false);
        let req = Json::parse(r#"{"id":1,"spec":{"c":4,"h":8,"w":8,"k":4},"seed":1}"#).unwrap();
        let resp = request_once(&server.addr, &req).unwrap();
        assert_eq!(resp.get(&["ok"]).unwrap().as_bool(), Some(true), "{resp:?}");
        server.stop();
    }

    #[test]
    fn stop_drains_idle_connections_instead_of_hanging() {
        let server = start_n(1);
        // An idle keep-alive client: no request, connection held open.
        let (_hello, stream, _reader) = connect_raw(server.addr);
        let t0 = std::time::Instant::now();
        server.stop();
        assert!(
            t0.elapsed() < Duration::from_secs(5),
            "stop() must drain handlers via the shutdown poll, not block on the idle client"
        );
        drop(stream);
    }

    // ---- wire v3: binary framing, negotiation, exact integers ----

    #[test]
    fn v2_only_hello_advertises_proto_2_without_bin() {
        let server = TcpServer::start(
            "127.0.0.1:0",
            CoordinatorConfig::default().with_cores(1).with_wire_v2_only(),
        )
        .unwrap();
        let (hello, _stream, _reader) = connect_raw(server.addr);
        let h = hello.get(&["hello"]).expect("hello frame");
        assert_eq!(h.get(&["proto"]).unwrap().as_usize(), Some(2));
        assert!(h.get(&["bin"]).is_none(), "legacy endpoint must not offer binary framing");
        assert!(h.get(&["wcache"]).is_none(), "legacy endpoint must not offer weight caching");
        assert!(h.get(&["trace"]).is_none(), "legacy endpoint must not offer tracing");
        // Ping stays negotiated within v2 (it predates v3).
        assert_eq!(h.get(&["ping"]).unwrap().as_bool(), Some(true));
        // JSON-tensor traffic is served normally.
        let req = Json::parse(r#"{"id":1,"spec":{"c":4,"h":8,"w":8,"k":4},"seed":1}"#).unwrap();
        let resp = request_once(&server.addr, &req).unwrap();
        assert_eq!(resp.get(&["ok"]).unwrap().as_bool(), Some(true), "{resp:?}");
        server.stop();
    }

    #[test]
    fn traced_request_gets_server_timing_and_v2_never_serves_it() {
        // Telemetry negotiation, server side: a traced request to a v4
        // endpoint is answered with the server-side queue/compute
        // decomposition; an untraced request on the same endpoint is
        // not; and a v2-pinned endpoint ignores a stray trace field
        // entirely — it must provably never serve a trace reply field.
        let server = start();
        let traced =
            Json::parse(r#"{"id":1,"spec":{"c":4,"h":8,"w":8,"k":4},"seed":1,"trace":9}"#)
                .unwrap();
        let resp = request_once(&server.addr, &traced).unwrap();
        assert_eq!(resp.get(&["ok"]).unwrap().as_bool(), Some(true), "{resp:?}");
        assert!(
            resp.get(&["queue_us"]).and_then(Json::as_u64).is_some(),
            "traced reply must decompose queue time: {resp:?}"
        );
        assert!(
            resp.get(&["compute_us"]).and_then(Json::as_u64).is_some(),
            "traced reply must decompose compute time: {resp:?}"
        );
        let plain = Json::parse(r#"{"id":2,"spec":{"c":4,"h":8,"w":8,"k":4},"seed":1}"#).unwrap();
        let resp = request_once(&server.addr, &plain).unwrap();
        assert_eq!(resp.get(&["ok"]).unwrap().as_bool(), Some(true), "{resp:?}");
        assert!(resp.get(&["queue_us"]).is_none(), "untraced reply must omit timing");
        assert!(resp.get(&["compute_us"]).is_none());
        server.stop();
        let legacy = TcpServer::start(
            "127.0.0.1:0",
            CoordinatorConfig::default().with_cores(1).with_wire_v2_only(),
        )
        .unwrap();
        let resp = request_once(&legacy.addr, &traced).unwrap();
        assert_eq!(resp.get(&["ok"]).unwrap().as_bool(), Some(true), "{resp:?}");
        assert!(
            resp.get(&["queue_us"]).is_none() && resp.get(&["compute_us"]).is_none(),
            "a v2 endpoint must never serve trace reply fields: {resp:?}"
        );
        legacy.stop();
    }

    #[test]
    fn binary_frames_round_trip_bit_identical() {
        let server = start();
        let (hello, mut stream, mut reader) = connect_raw(server.addr);
        assert_eq!(
            hello.get(&["hello"]).unwrap().get(&["bin"]).unwrap().as_bool(),
            Some(true)
        );
        // Standard conv, binary both ways.
        let spec = LayerSpec::new(2, 5, 5, 4);
        let mut rng = Prng::new(93);
        let img = rng.bytes_below(spec.c * spec.h * spec.w, 256);
        let wts = rng.bytes_below(spec.k * spec.c * 9, 256);
        let bias: Vec<i32> = (0..spec.k).map(|_| rng.range_i64(-20, 20) as i32).collect();
        let frame = encode_request_frame(
            11,
            JobKind::Standard,
            &spec,
            &img,
            &wts,
            &bias,
            true,
            true,
        );
        stream.write_all(&frame).unwrap();
        let (header, body) = read_reply_frame(&mut reader);
        assert_eq!(header.get(&["ok"]).unwrap().as_bool(), Some(true), "{header:?}");
        assert_eq!(header.get(&["id"]).unwrap().as_u64(), Some(11));
        assert!(
            header.get(&["output"]).is_none(),
            "binary reply must not also carry the JSON output array"
        );
        let shape: Vec<usize> = header
            .get(&["shape"])
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|v| v.as_usize().unwrap())
            .collect();
        assert_eq!(shape, vec![4, 3, 3]);
        let img_t = Tensor::from_vec(&[2, 5, 5], img);
        let wts_t = Tensor::from_vec(&[4, 2, 3, 3], wts);
        let want = golden::conv3x3_i32(&img_t, &wts_t, &bias, false);
        assert_eq!(
            body.expect("bin_output body"),
            want.data(),
            "binary full output must be bit-identical"
        );
        // Depthwise+relu on the *same connection* (framing stays in
        // sync across kinds).
        let dspec = LayerSpec::new(8, 10, 10, 8).with_relu();
        let dimg = rng.bytes_below(8 * 10 * 10, 256);
        let dwts = rng.bytes_below(8 * 9, 256);
        let dbias: Vec<i32> = (0..8).map(|_| rng.range_i64(-100, 100) as i32).collect();
        let frame = encode_request_frame(
            12,
            JobKind::Depthwise,
            &dspec,
            &dimg,
            &dwts,
            &dbias,
            true,
            true,
        );
        stream.write_all(&frame).unwrap();
        let (header, body) = read_reply_frame(&mut reader);
        assert_eq!(header.get(&["ok"]).unwrap().as_bool(), Some(true), "{header:?}");
        assert_eq!(header.get(&["id"]).unwrap().as_u64(), Some(12));
        let dimg_t = Tensor::from_vec(&[8, 10, 10], dimg);
        let dwts_t = Tensor::from_vec(&[8, 3, 3], dwts);
        let dwant = golden_depthwise3x3(&dimg_t, &dwts_t, &dbias, true);
        assert_eq!(body.expect("bin_output body"), dwant.data());
        server.stop();
    }

    #[test]
    fn malformed_binary_frame_fails_the_job_not_the_connection() {
        let server = start_n(1);
        let (_hello, mut stream, mut reader) = connect_raw(server.addr);
        // Self-consistent framing (12+36+16 bytes really follow) but
        // wrong for the spec: img wants c*h*w = 16 bytes, not 12. The
        // server must consume exactly the declared bytes, error the
        // job, and keep the stream in sync.
        let header =
            r#"{"id":1,"spec":{"c":1,"h":4,"w":4,"k":4},"bin":[12,36,16],"full_output":true}"#;
        stream.write_all(header.as_bytes()).unwrap();
        stream.write_all(b"\n").unwrap();
        stream.write_all(&vec![0u8; 12 + 36 + 16]).unwrap();
        let (resp, body) = read_reply_frame(&mut reader);
        assert_eq!(resp.get(&["ok"]).unwrap().as_bool(), Some(false), "{resp:?}");
        assert!(resp
            .get(&["error"])
            .unwrap()
            .as_str()
            .unwrap()
            .contains("img length"));
        assert!(body.is_none());
        // The same connection serves a well-formed binary frame next.
        let spec = LayerSpec::new(1, 4, 4, 4);
        let img: Vec<u8> = (0..16).collect();
        let wts: Vec<u8> = (0..36).map(|i| (i % 5) as u8).collect();
        let frame =
            encode_request_frame(2, JobKind::Standard, &spec, &img, &wts, &[0; 4], false, true);
        stream.write_all(&frame).unwrap();
        let (resp, _body) = read_reply_frame(&mut reader);
        assert_eq!(resp.get(&["ok"]).unwrap().as_bool(), Some(true), "{resp:?}");
        assert_eq!(resp.get(&["id"]).unwrap().as_u64(), Some(2));
        server.stop();
    }

    #[test]
    fn oversized_binary_declaration_severs_the_connection() {
        let server = start_n(1);
        let (_hello, mut stream, mut reader) = connect_raw(server.addr);
        // Declares more than MAX_BIN_BYTES: the server must answer an
        // error *without* trying to consume (or allocate) the payload,
        // then sever — it cannot resync past an unconsumed body.
        let too_big = MAX_BIN_BYTES; // 3*cap total > cap
        let header = format!(
            r#"{{"id":1,"spec":{{"c":1,"h":4,"w":4,"k":4}},"bin":[{too_big},{too_big},{too_big}]}}"#
        );
        let t0 = std::time::Instant::now();
        stream.write_all(header.as_bytes()).unwrap();
        stream.write_all(b"\n").unwrap();
        let (resp, _body) = read_reply_frame(&mut reader);
        assert_eq!(resp.get(&["ok"]).unwrap().as_bool(), Some(false), "{resp:?}");
        assert!(resp
            .get(&["error"])
            .unwrap()
            .as_str()
            .unwrap()
            .contains("exceeds cap"));
        // Then EOF: the connection is gone, quickly (no 192 MB read).
        let mut line = String::new();
        let n = reader.read_line(&mut line).unwrap_or(0);
        assert_eq!(n, 0, "oversized declaration must sever: {line:?}");
        assert!(t0.elapsed() < Duration::from_secs(5));
        server.stop();
    }

    #[test]
    fn binary_request_to_v2_only_endpoint_fails_cleanly() {
        let server = TcpServer::start(
            "127.0.0.1:0",
            CoordinatorConfig::default().with_cores(1).with_wire_v2_only(),
        )
        .unwrap();
        let (_hello, mut stream, mut reader) = connect_raw(server.addr);
        let spec = LayerSpec::new(1, 4, 4, 4);
        let img: Vec<u8> = (0..16).collect();
        let wts: Vec<u8> = (0..36).map(|i| (i % 5) as u8).collect();
        // A client that ignores negotiation and sends binary anyway:
        // the v2-only server consumes the declared bytes and answers a
        // clean per-job error — no disconnect, no desync.
        let frame =
            encode_request_frame(9, JobKind::Standard, &spec, &img, &wts, &[0; 4], false, true);
        stream.write_all(&frame).unwrap();
        let (resp, _body) = read_reply_frame(&mut reader);
        assert_eq!(resp.get(&["ok"]).unwrap().as_bool(), Some(false), "{resp:?}");
        assert!(resp
            .get(&["error"])
            .unwrap()
            .as_str()
            .unwrap()
            .contains("not negotiated"));
        assert_eq!(resp.get(&["id"]).unwrap().as_u64(), Some(9));
        // Same connection, JSON tensors: served.
        let frame =
            encode_request_frame(10, JobKind::Standard, &spec, &img, &wts, &[0; 4], false, false);
        stream.write_all(&frame).unwrap();
        let (resp, _body) = read_reply_frame(&mut reader);
        assert_eq!(resp.get(&["ok"]).unwrap().as_bool(), Some(true), "{resp:?}");
        assert_eq!(resp.get(&["id"]).unwrap().as_u64(), Some(10));
        server.stop();
    }

    #[test]
    fn ids_above_2_pow_53_survive_the_wire_exactly() {
        // Regression: v2 rendered ids via `Json::num(id as f64)`, which
        // corrupts anything above 2^53 (and checksums likewise). v3
        // emits exact integers.
        let server = start_n(1);
        let big: u64 = (1u64 << 60) + 3;
        let req = Json::parse(&format!(
            r#"{{"id":{big},"spec":{{"c":4,"h":8,"w":8,"k":4}},"seed":1}}"#
        ))
        .unwrap();
        assert_eq!(req.get(&["id"]).unwrap().as_u64(), Some(big), "parse side");
        let resp = request_once(&server.addr, &req).unwrap();
        assert_eq!(resp.get(&["ok"]).unwrap().as_bool(), Some(true), "{resp:?}");
        assert_eq!(
            resp.get(&["id"]).unwrap().as_u64(),
            Some(big),
            "id must round-trip digit-for-digit, not via f64"
        );
        // The error path echoes exact ids too.
        let bad = Json::parse(&format!(
            r#"{{"id":{big},"kind":"pointwise","spec":{{"c":4,"h":8,"w":8,"k":4}},"seed":1}}"#
        ))
        .unwrap();
        let resp = request_once(&server.addr, &bad).unwrap();
        assert_eq!(resp.get(&["ok"]).unwrap().as_bool(), Some(false));
        assert_eq!(resp.get(&["id"]).unwrap().as_u64(), Some(big));
        server.stop();
    }

    // ---- wire v4: content-addressed weights ----

    #[test]
    fn hash_only_request_round_trips_need_weights_then_hits() {
        let server = start_n(1);
        let spec = LayerSpec::new(2, 5, 5, 4);
        let mut rng = Prng::new(94);
        let img = rng.bytes_below(spec.c * spec.h * spec.w, 256);
        let wts = rng.bytes_below(spec.k * spec.c * 9, 256);
        let bias: Vec<i32> = (0..spec.k).map(|_| rng.range_i64(-20, 20) as i32).collect();
        let hash = fnv1a_bytes(&wts);
        let (hello, mut stream, mut reader) = connect_raw(server.addr);
        assert_eq!(
            hello.get(&["hello"]).unwrap().get(&["wcache"]).unwrap().as_bool(),
            Some(true)
        );
        // 1. Hash-only against a cold store: a fast need_weights miss,
        //    well-formed for pre-v4 clients (ok:false + error).
        let frame = encode_request_frame_v4(
            1, JobKind::Standard, &spec, &img, None, Some(hash), &bias, true, true, 0,
        );
        stream.write_all(&frame).unwrap();
        let (resp, body) = read_reply_frame(&mut reader);
        assert_eq!(resp.get(&["ok"]).unwrap().as_bool(), Some(false), "{resp:?}");
        assert_eq!(resp.get(&["need_weights"]).unwrap().as_bool(), Some(true));
        assert_eq!(resp.get(&["weights_hash"]).unwrap().as_u64(), Some(hash));
        assert!(resp.get(&["error"]).is_some());
        assert!(body.is_none());
        // 2. Re-ship inline with the hash: served, blob admitted.
        let frame = encode_request_frame_v4(
            2, JobKind::Standard, &spec, &img, Some(&wts), Some(hash), &bias, true, true, 0,
        );
        stream.write_all(&frame).unwrap();
        let (resp, body) = read_reply_frame(&mut reader);
        assert_eq!(resp.get(&["ok"]).unwrap().as_bool(), Some(true), "{resp:?}");
        let img_t = Tensor::from_vec(&[2, 5, 5], img.clone());
        let wts_t = Tensor::from_vec(&[4, 2, 3, 3], wts.clone());
        let want = golden::conv3x3_i32(&img_t, &wts_t, &bias, false);
        assert_eq!(body.expect("bin_output body"), want.data());
        // 3. Hash-only again — on a *new* connection, because residency
        //    is per server, not per connection: bit-identical output,
        //    zero weight bytes on the wire.
        let (_h2, mut s2, mut r2) = connect_raw(server.addr);
        let frame = encode_request_frame_v4(
            3, JobKind::Standard, &spec, &img, None, Some(hash), &bias, true, true, 0,
        );
        s2.write_all(&frame).unwrap();
        let (resp, body) = read_reply_frame(&mut r2);
        assert_eq!(resp.get(&["ok"]).unwrap().as_bool(), Some(true), "{resp:?}");
        assert_eq!(body.expect("bin_output body"), want.data());
        // 4. The JSON hash-only form resolves against the same store.
        let frame = encode_request_frame_v4(
            4, JobKind::Standard, &spec, &img, None, Some(hash), &bias, false, false, 0,
        );
        s2.write_all(&frame).unwrap();
        let (resp, _body) = read_reply_frame(&mut r2);
        assert_eq!(resp.get(&["ok"]).unwrap().as_bool(), Some(true), "{resp:?}");
        let checksum = want
            .data()
            .iter()
            .fold(0i64, |a, &v| (a + v as i64) & 0x7FFF_FFFF);
        assert_eq!(resp.get(&["checksum"]).unwrap().as_f64(), Some(checksum as f64));
        // Server-side accounting: one miss, two hits, the blob crossed
        // the wire exactly once.
        let m = server.metrics();
        assert_eq!(m.weight_misses.load(Ordering::Relaxed), 1);
        assert_eq!(m.weight_hits.load(Ordering::Relaxed), 2);
        assert_eq!(
            m.weight_bytes_saved.load(Ordering::Relaxed),
            2 * wts.len() as u64
        );
        assert_eq!(
            m.wire_weight_bytes.load(Ordering::Relaxed),
            wts.len() as u64,
            "each distinct blob ships at most once per server lifetime"
        );
        let store = server.weight_store().expect("v4 endpoint keeps a store");
        assert_eq!(store.len(), 1);
        assert!(store.contains(hash));
        drop(stream);
        drop(s2);
        server.stop();
    }

    #[test]
    fn mismatched_weights_hash_is_a_job_error_not_a_disconnect() {
        let server = start_n(1);
        let (_hello, mut stream, mut reader) = connect_raw(server.addr);
        let spec = LayerSpec::new(1, 4, 4, 4);
        let img: Vec<u8> = (0..16).collect();
        let wts: Vec<u8> = (0..36).map(|i| (i % 5) as u8).collect();
        let lie = fnv1a_bytes(&wts) ^ 1;
        let frame = encode_request_frame_v4(
            1, JobKind::Standard, &spec, &img, Some(&wts), Some(lie), &[0; 4], false, true, 0,
        );
        stream.write_all(&frame).unwrap();
        let (resp, _body) = read_reply_frame(&mut reader);
        assert_eq!(resp.get(&["ok"]).unwrap().as_bool(), Some(false), "{resp:?}");
        assert!(resp
            .get(&["error"])
            .unwrap()
            .as_str()
            .unwrap()
            .contains("does not match"));
        assert!(resp.get(&["need_weights"]).is_none());
        let store = server.weight_store().unwrap();
        assert!(store.is_empty(), "a lying client must not poison the store");
        // The connection survives and plain v3 inline weights (no
        // hash) are served without touching the store.
        let frame =
            encode_request_frame(2, JobKind::Standard, &spec, &img, &wts, &[0; 4], false, true);
        stream.write_all(&frame).unwrap();
        let (resp, _body) = read_reply_frame(&mut reader);
        assert_eq!(resp.get(&["ok"]).unwrap().as_bool(), Some(true), "{resp:?}");
        assert!(store.is_empty(), "un-addressed weights are never cached");
        assert_eq!(
            server.metrics().wire_weight_bytes.load(Ordering::Relaxed),
            wts.len() as u64,
            "only the accepted request's inline bytes are accounted"
        );
        server.stop();
    }

    #[test]
    fn weights_hash_to_v2_only_endpoint_fails_cleanly() {
        let server = TcpServer::start(
            "127.0.0.1:0",
            CoordinatorConfig::default().with_cores(1).with_wire_v2_only(),
        )
        .unwrap();
        assert!(server.weight_store().is_none(), "a v2 endpoint keeps no store");
        let (_hello, mut stream, mut reader) = connect_raw(server.addr);
        let spec = LayerSpec::new(1, 4, 4, 4);
        let img: Vec<u8> = (0..16).collect();
        // JSON hash-only form (a binary frame would trip the bin guard
        // before weight resolution).
        let frame = encode_request_frame_v4(
            1, JobKind::Standard, &spec, &img, None, Some(1234), &[0; 4], false, false, 0,
        );
        stream.write_all(&frame).unwrap();
        let (resp, _body) = read_reply_frame(&mut reader);
        assert_eq!(resp.get(&["ok"]).unwrap().as_bool(), Some(false), "{resp:?}");
        assert!(
            resp.get(&["need_weights"]).is_none(),
            "a v2 endpoint must not speak v4 frames"
        );
        assert!(resp
            .get(&["error"])
            .unwrap()
            .as_str()
            .unwrap()
            .contains("not negotiated"));
        // The connection survives for inline-tensor traffic.
        let wts: Vec<u8> = (0..36).map(|i| (i % 5) as u8).collect();
        let frame =
            encode_request_frame(2, JobKind::Standard, &spec, &img, &wts, &[0; 4], false, false);
        stream.write_all(&frame).unwrap();
        let (resp, _body) = read_reply_frame(&mut reader);
        assert_eq!(resp.get(&["ok"]).unwrap().as_bool(), Some(true), "{resp:?}");
        server.stop();
    }

    #[test]
    fn tiny_store_evicts_lru_and_round_trips_need_weights() {
        // One BRAM36 block = 4608 bytes; a 16-in/16-out 3x3 blob is
        // priced at demand().weight_bytes = 2304, so the store holds
        // exactly two blobs and the third insert evicts the LRU one.
        let server = TcpServer::start(
            "127.0.0.1:0",
            CoordinatorConfig::default()
                .with_cores(1)
                .with_weight_store_bram36(1),
        )
        .unwrap();
        let store = server.weight_store().unwrap();
        assert_eq!(store.capacity_bytes(), 4608);
        let spec = LayerSpec::new(16, 6, 6, 16);
        let mut rng = Prng::new(95);
        let img = rng.bytes_below(16 * 6 * 6, 256);
        let bias = vec![0i32; 16];
        let blobs: Vec<Vec<u8>> = (0..3).map(|_| rng.bytes_below(2304, 256)).collect();
        let hashes: Vec<u64> = blobs.iter().map(|b| fnv1a_bytes(b)).collect();
        let (_hello, mut stream, mut reader) = connect_raw(server.addr);
        for (i, (blob, hash)) in blobs.iter().zip(&hashes).enumerate() {
            let frame = encode_request_frame_v4(
                i as u64 + 1,
                JobKind::Standard,
                &spec,
                &img,
                Some(blob),
                Some(*hash),
                &bias,
                false,
                true,
                0,
            );
            stream.write_all(&frame).unwrap();
            let (resp, _b) = read_reply_frame(&mut reader);
            assert_eq!(resp.get(&["ok"]).unwrap().as_bool(), Some(true), "{resp:?}");
        }
        assert_eq!(store.len(), 2);
        assert!(!store.contains(hashes[0]), "blob 0 is the LRU victim");
        assert!(store.contains(hashes[1]) && store.contains(hashes[2]));
        // A resident blob answers hash-only (and refreshes recency).
        let frame = encode_request_frame_v4(
            4, JobKind::Standard, &spec, &img, None, Some(hashes[1]), &bias, false, true, 0,
        );
        stream.write_all(&frame).unwrap();
        let (resp, _b) = read_reply_frame(&mut reader);
        assert_eq!(resp.get(&["ok"]).unwrap().as_bool(), Some(true), "{resp:?}");
        // The evicted blob round-trips: need_weights, inline re-ship,
        // resident again (evicting blob 2, now the least recent).
        let frame = encode_request_frame_v4(
            5, JobKind::Standard, &spec, &img, None, Some(hashes[0]), &bias, false, true, 0,
        );
        stream.write_all(&frame).unwrap();
        let (resp, _b) = read_reply_frame(&mut reader);
        assert_eq!(resp.get(&["need_weights"]).unwrap().as_bool(), Some(true), "{resp:?}");
        let frame = encode_request_frame_v4(
            6,
            JobKind::Standard,
            &spec,
            &img,
            Some(&blobs[0]),
            Some(hashes[0]),
            &bias,
            false,
            true,
            0,
        );
        stream.write_all(&frame).unwrap();
        let (resp, _b) = read_reply_frame(&mut reader);
        assert_eq!(resp.get(&["ok"]).unwrap().as_bool(), Some(true), "{resp:?}");
        assert!(store.contains(hashes[0]) && store.contains(hashes[1]));
        assert!(!store.contains(hashes[2]));
        let m = server.metrics();
        assert_eq!(m.weight_hits.load(Ordering::Relaxed), 1);
        assert_eq!(m.weight_misses.load(Ordering::Relaxed), 1);
        server.stop();
    }

    #[test]
    fn i32_le_codec_round_trips() {
        let words = vec![0i32, -1, i32::MIN, i32::MAX, 7, -4096];
        let bytes = encode_i32_le(&words);
        assert_eq!(bytes.len(), words.len() * 4);
        assert_eq!(decode_i32_le(&bytes), words);
    }
}
