//! Analytic resource model — regenerates Table 1 without Vivado.
//!
//! The model is a *component census* of the RTL: every structural unit
//! of the architecture (144 MACs, 16 adder trees, 8 loaders, FSM, AXI
//! glue, address generators) with per-unit LUT/FF costs. 7-series costs
//! are calibrated once against the paper's clg400 row; the UltraScale+
//! row calibrates a family factor (the paper's ZU3EG build uses *more*
//! logic — consistent with the toolchain not inferring DSP48s on that
//! target and the wider control FFs; we carry the factor, and say so,
//! rather than pretend a synthesis we cannot run).
//!
//! What the model is for: (a) regenerating Table 1's shape —
//! utilisation <10 % LUT / <5 % FF on the Z-7020 parts, higher on
//! ZU3EG, fmax ordering 484 < 400 < ZU3EG; (b) the max-cores analysis
//! behind the paper's "20 cores ⇒ 4.48 GOPS" claim, including the
//! honest observation that Table 1's own LUT numbers cap a Z-7020 at
//! 10 replicas of the *full* IP core.

use super::device::{Device, Family, TABLE1_DEVICES};
use crate::paper::{N_CORES, N_PCORES};

/// Per-unit LUT/FF cost of one structural component.
#[derive(Clone, Copy, Debug)]
pub struct UnitCost {
    pub name: &'static str,
    pub count: u64,
    pub lut: u64,
    pub ff: u64,
}

/// The structural census of the IP core (counts from §4.2; per-unit
/// 7-series costs calibrated to Table 1 row 1).
pub fn census() -> Vec<UnitCost> {
    let macs = (N_CORES * N_PCORES * 9) as u64; // 144
    let trees = (N_CORES * N_PCORES) as u64; // 16
    let cores = N_CORES as u64;
    vec![
        UnitCost {
            name: "mac (8x8 mult + acc)",
            count: macs,
            lut: 22,
            ff: 18,
        },
        UnitCost {
            name: "pcore adder tree",
            count: trees,
            lut: 30,
            ff: 27,
        },
        UnitCost {
            name: "image loader",
            count: cores,
            lut: 110,
            ff: 130,
        },
        UnitCost {
            name: "weight loader",
            count: cores,
            lut: 90,
            ff: 110,
        },
        UnitCost {
            name: "controller fsm",
            count: 1,
            lut: 150,
            ff: 170,
        },
        UnitCost {
            name: "axi/dma glue",
            count: 1,
            lut: 180,
            ff: 220,
        },
        UnitCost {
            name: "bram address gen",
            count: 12, // 4 image + 4 output + 4 weight groups
            lut: 20,
            ff: 48,
        },
    ]
}

/// Family scaling relative to the calibrated 7-series costs.
fn family_factors(family: Family) -> (f64, f64) {
    match family {
        Family::Series7 => (1.0, 1.0),
        // Calibrated on the paper's ZU3EG row (11917 LUT / 14522 FF vs
        // the 7-series census): no DSP inference + wider control regs.
        Family::UltraScalePlus => (2.375, 2.934),
    }
}

/// Model output for one device.
#[derive(Clone, Debug)]
pub struct ResourceEstimate {
    pub device: Device,
    pub luts: u64,
    pub ffs: u64,
    pub lut_pct: f64,
    pub ff_pct: f64,
    pub fmax_mhz: f64,
}

/// Paper's Table 1, for tolerance checks.
#[derive(Clone, Copy, Debug)]
pub struct Table1Row {
    pub device: &'static str,
    pub luts: u64,
    pub ffs: u64,
    pub fmax_mhz: f64,
}

pub const PAPER_TABLE1: [Table1Row; 3] = [
    Table1Row {
        device: "xc7z020clg400-1",
        luts: 5027,
        ffs: 4959,
        fmax_mhz: 112.0,
    },
    Table1Row {
        device: "xc7z020clg484-1",
        luts: 5243,
        ffs: 5054,
        fmax_mhz: 93.0,
    },
    Table1Row {
        device: "xzcu3eg-sbva484-1-i",
        luts: 11917,
        ffs: 14522,
        fmax_mhz: 161.0,
    },
];

/// Estimate the full IP core on one device.
pub fn estimate(device: &Device) -> ResourceEstimate {
    let (flut, fff) = family_factors(device.family);
    let (mut luts, mut ffs) = (0f64, 0f64);
    for u in census() {
        luts += (u.count * u.lut) as f64;
        ffs += (u.count * u.ff) as f64;
    }
    let luts = (luts * flut).round() as u64;
    let ffs = (ffs * fff).round() as u64;
    ResourceEstimate {
        device: *device,
        luts,
        ffs,
        lut_pct: luts as f64 / device.luts as f64 * 100.0,
        ff_pct: ffs as f64 / device.ffs as f64 * 100.0,
        fmax_mhz: device.fmax_mhz(),
    }
}

/// Regenerate Table 1 (all three devices).
pub fn table1() -> Vec<ResourceEstimate> {
    TABLE1_DEVICES.iter().map(estimate).collect()
}

/// Render the table in the paper's layout.
pub fn render_table1() -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "{:<22} {:>16} {:>16} {:>14}\n",
        "FPGA", "#LUTs", "#FF", "Max frequency"
    ));
    for e in table1() {
        out.push_str(&format!(
            "{:<22} {:>7} ({:>5.2}%) {:>7} ({:>5.2}%) {:>10.0} MHz\n",
            e.device.name, e.luts, e.lut_pct, e.ffs, e.ff_pct, e.fmax_mhz
        ));
    }
    out
}

/// How many *full IP cores* fit on a device by each resource, and the
/// binding constraint. The paper claims 20 via its "<5 % per core"
/// reading; Table 1's own LUT row binds a Z-7020 at 10 — we report both
/// (EXPERIMENTS.md discusses the discrepancy).
#[derive(Clone, Copy, Debug)]
pub struct MaxCores {
    pub by_lut: u64,
    pub by_ff: u64,
    pub binding: u64,
}

pub fn max_cores(device: &Device) -> MaxCores {
    let e = estimate(device);
    let by_lut = device.luts / e.luts.max(1);
    let by_ff = device.ffs / e.ffs.max(1);
    MaxCores {
        by_lut,
        by_ff,
        binding: by_lut.min(by_ff),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::device::{XC7Z020_CLG400, XZCU3EG_SBVA484};

    #[test]
    fn census_totals_calibrated_to_clg400_row() {
        let e = estimate(&XC7Z020_CLG400);
        let paper = PAPER_TABLE1[0];
        let lut_err = (e.luts as f64 - paper.luts as f64).abs() / paper.luts as f64;
        let ff_err = (e.ffs as f64 - paper.ffs as f64).abs() / paper.ffs as f64;
        assert!(lut_err < 0.01, "LUT {} vs paper {}", e.luts, paper.luts);
        assert!(ff_err < 0.01, "FF {} vs paper {}", e.ffs, paper.ffs);
    }

    #[test]
    fn all_rows_within_tolerance() {
        // 5% absorbs P&R variance across packages (clg484 row).
        for (e, paper) in table1().iter().zip(PAPER_TABLE1.iter()) {
            assert_eq!(e.device.name, paper.device);
            let lut_err = (e.luts as f64 - paper.luts as f64).abs() / paper.luts as f64;
            let ff_err = (e.ffs as f64 - paper.ffs as f64).abs() / paper.ffs as f64;
            assert!(lut_err < 0.05, "{}: LUT {} vs {}", paper.device, e.luts, paper.luts);
            assert!(ff_err < 0.05, "{}: FF {} vs {}", paper.device, e.ffs, paper.ffs);
            assert!((e.fmax_mhz - paper.fmax_mhz).abs() < 1.0);
        }
    }

    #[test]
    fn utilisation_shape_matches_paper_claims() {
        let z2 = estimate(&XC7Z020_CLG400);
        assert!(z2.lut_pct < 10.0, "under 10% LUTs on the Z-7020");
        assert!(z2.ff_pct < 5.0, "under 5% FFs on the Z-7020");
        let zu = estimate(&XZCU3EG_SBVA484);
        assert!(zu.lut_pct > z2.lut_pct, "ZU3EG row uses more logic");
    }

    #[test]
    fn max_cores_analysis() {
        let m = max_cores(&XC7Z020_CLG400);
        assert_eq!(m.by_lut, 10, "Table 1's own LUT numbers bind at 10");
        assert!(m.by_ff >= 20, "the paper's 20-core claim holds by FFs");
        assert_eq!(m.binding, 10);
    }

    #[test]
    fn render_contains_all_devices() {
        let t = render_table1();
        for d in &PAPER_TABLE1 {
            assert!(t.contains(d.device));
        }
    }
}
