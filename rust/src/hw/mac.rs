//! MAC (Multiply-and-ACcumulate) primitives — §4.2: "Each [PCORE]
//! contains a set of MAC units and adder modules to perform a
//! weighted-sum operation."
//!
//! Both accumulator widths are provided; the 8-bit wrapping form is what
//! the synthesised core computes (Fig. 6), the 32-bit form is the
//! production configuration.

/// One 8-bit wrapping multiply-accumulate step: `acc + a*b (mod 256)`.
#[inline(always)]
pub fn mac_wrap8(acc: u8, a: u8, b: u8) -> u8 {
    acc.wrapping_add(a.wrapping_mul(b))
}

/// One wide multiply-accumulate step over u8 operands.
#[inline(always)]
pub fn mac_i32(acc: i32, a: u8, b: u8) -> i32 {
    acc + (a as i32) * (b as i32)
}

/// 9-tap weighted sum with 8-bit wrap — one PCORE dot product.
#[inline]
pub fn dot9_wrap8(window: &[u8; 9], weights: &[u8; 9]) -> u8 {
    let mut acc = 0u8;
    for i in 0..9 {
        acc = mac_wrap8(acc, window[i], weights[i]);
    }
    acc
}

/// 9-tap weighted sum, wide accumulation — one PCORE dot product.
#[inline]
pub fn dot9_i32(window: &[u8; 9], weights: &[u8; 9]) -> i32 {
    let mut acc = 0i32;
    for i in 0..9 {
        acc = mac_i32(acc, window[i], weights[i]);
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wrap8_wraps() {
        assert_eq!(mac_wrap8(250, 2, 5), 4); // 250 + 10 = 260 -> 4
        assert_eq!(mac_wrap8(0, 16, 16), 0); // 256 -> 0
        assert_eq!(mac_wrap8(1, 255, 255), 2); // 1 + 65025 mod 256 = 1+1
    }

    #[test]
    fn i32_never_wraps_for_u8_operands() {
        // 9 * 255 * 255 * many channels stays far inside i32.
        let mut acc = 0i32;
        for _ in 0..9 * 1024 {
            acc = mac_i32(acc, 255, 255);
        }
        assert_eq!(acc, 9 * 1024 * 255 * 255);
    }

    #[test]
    fn dot9_matches_fig6_first_psum() {
        // Fig. 6 window 1: weights 01..09 over the ramp window -> 0x9b.
        let w: [u8; 9] = [1, 2, 3, 4, 5, 6, 7, 8, 9];
        let win: [u8; 9] = [0x01, 0x02, 0x03, 0x06, 0x07, 0x08, 0x0b, 0x0c, 0x0d];
        assert_eq!(dot9_wrap8(&win, &w), 0x9b);
        assert_eq!(dot9_i32(&win, &w) % 256, 0x9b);
    }

    #[test]
    fn dot9_wide_equals_wrap_mod_256() {
        let w: [u8; 9] = [17, 250, 3, 91, 5, 66, 7, 128, 9];
        let win: [u8; 9] = [200, 2, 31, 6, 77, 8, 111, 12, 13];
        assert_eq!((dot9_i32(&win, &w) % 256) as u8, dot9_wrap8(&win, &w));
    }
}
