"""L1 Pallas kernel: weight-stationary 3x3 convolution.

TPU adaptation of the paper's IP core (DESIGN.md §Hardware-Adaptation):

* the paper splits input channels across **4 computing cores** and
  kernels across **4 PCOREs** — here that is the Pallas grid
  ``(K/kblk, C/cblk)``: one grid step owns one (kernel-block,
  channel-block) tile pair;
* the paper's **weight loader** keeps weights resident next to the MACs
  while the image loader streams 3x3 windows — here the weight tile
  ``(kblk, cblk, 3, 3)`` is staged into VMEM by its BlockSpec and reused
  across the whole spatial extent of the grid step (weight-stationary);
* the paper's **PCORE** (9 MACs + adder tree) becomes an im2col matmul
  ``(OH·OW, 9·cblk) @ (9·cblk, kblk)`` that the MXU executes — the
  systolic array replaces the adder tree;
* the paper's **accumulating output BRAM** (which also absorbs the bias,
  §4.2 "Bias Handling") is the revisited output block: channel-block
  grid steps accumulate into the same ``o_ref``, and step 0 initialises
  it with the bias exactly like the PS pre-loading the output BMGs.

The kernel is lowered with ``interpret=True`` — CPU PJRT cannot run
Mosaic custom-calls; real-TPU efficiency is estimated from the VMEM
footprint of these tiles in DESIGN.md §Perf.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

KH = KW = 3
TAPS = tuple((dy, dx) for dy in range(KH) for dx in range(KW))


def _conv_block(x, w):
    """One grid step's compute: (cblk,H,W) image tile x (kblk,cblk,3,3) weights.

    Returns the (kblk, OH, OW) partial feature map for this channel block.
    """
    cblk, h, width = x.shape
    kblk = w.shape[0]
    oh, ow = h - KH + 1, width - KW + 1
    # im2col gather: 9 shifted slabs -> (OH*OW, cblk*9) patch matrix.
    slabs = [x[:, dy : dy + oh, dx : dx + ow] for (dy, dx) in TAPS]
    patches = jnp.stack(slabs, axis=-1)  # (cblk, OH, OW, 9)
    patches = patches.transpose(1, 2, 0, 3).reshape(oh * ow, cblk * KH * KW)
    # Weight tile flattens to the same (cblk*9) contraction order.
    wmat = w.reshape(kblk, cblk * KH * KW).T
    acc = jnp.dot(patches, wmat, preferred_element_type=jnp.float32)
    return acc.T.reshape(kblk, oh, ow)


def _kernel(img_ref, w_ref, b_ref, o_ref, *, ncblk: int, relu: bool):
    cc = pl.program_id(1)
    psum = _conv_block(img_ref[...], w_ref[...])

    @pl.when(cc == 0)
    def _init():  # bias pre-load, as the PS initialises the output BMGs
        o_ref[...] = psum + b_ref[...][:, None, None]

    @pl.when(cc > 0)
    def _accumulate():  # PSUM accumulation into the output BRAM
        o_ref[...] = o_ref[...] + psum

    if relu:

        @pl.when(cc == ncblk - 1)
        def _activate():
            o_ref[...] = jnp.maximum(o_ref[...], 0.0)


def conv3x3(img, w, bias, *, kblk: int = 4, cblk: int | None = None, relu: bool = False):
    """Weight-stationary 3x3 valid convolution via Pallas.

    Args:
      img:  ``(C, H, W)`` feature map (f32 carrying exact small ints).
      w:    ``(K, C, 3, 3)`` kernels.
      bias: ``(K,)`` bias.
      kblk: kernels per grid step (the paper's PCORE group: 4).
      cblk: channels per grid step; defaults to ``C // 4`` (the paper's
            4 computing cores), falling back to ``C`` when ``C < 4``.
      relu: fuse a ReLU into the last channel-block step.

    Returns:
      ``(K, H-2, W-2)`` feature map, f32.
    """
    c, h, width = img.shape
    k = w.shape[0]
    assert w.shape == (k, c, KH, KW), w.shape
    assert bias.shape == (k,), bias.shape
    if cblk is None:
        cblk = c // 4 if c % 4 == 0 and c >= 4 else c
    kblk = min(kblk, k)
    assert k % kblk == 0, f"K={k} not divisible by kblk={kblk} (paper: K % 4 == 0)"
    assert c % cblk == 0, f"C={c} not divisible by cblk={cblk} (paper: C % 4 == 0)"
    nkblk, ncblk = k // kblk, c // cblk
    oh, ow = h - KH + 1, width - KW + 1

    kernel = functools.partial(_kernel, ncblk=ncblk, relu=relu)
    return pl.pallas_call(
        kernel,
        grid=(nkblk, ncblk),
        in_specs=[
            pl.BlockSpec((cblk, h, width), lambda kk, cc: (cc, 0, 0)),
            pl.BlockSpec((kblk, cblk, KH, KW), lambda kk, cc: (kk, cc, 0, 0)),
            pl.BlockSpec((kblk,), lambda kk, cc: (kk,)),
        ],
        out_specs=pl.BlockSpec((kblk, oh, ow), lambda kk, cc: (kk, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((k, oh, ow), jnp.float32),
        interpret=True,
    )(img.astype(jnp.float32), w.astype(jnp.float32), bias.astype(jnp.float32))


def block_candidates(c: int, k: int):
    """All legal (kblk, cblk) decompositions for a (C, K) layer.

    kblk divides K (PCORE group), cblk divides C (computing-core split).
    """
    kblks = [b for b in (1, 2, 4, 8, 16) if b <= k and k % b == 0]
    cblks = [b for b in range(1, c + 1) if c % b == 0]
    return [(kb, cb) for kb in kblks for cb in cblks]


def choose_blocks(c: int, h: int, w: int, k: int, vmem_budget: int = 16 * 2**20):
    """§Perf L1: pick the (kblk, cblk) with the best MXU fill whose grid
    step fits the VMEM budget; ties break toward fewer grid steps (fewer
    HBM refetches of the image block).

    This is the TPU analogue of the paper's fixed 4×4 decomposition —
    where the FPGA freezes the split in silicon, the kernel re-derives
    it per layer shape.
    """
    best = None
    for kb, cb in block_candidates(c, k):
        fp = vmem_footprint_bytes(c, h, w, k, kblk=kb, cblk=cb)
        if fp["total_bytes"] > vmem_budget:
            continue
        steps = (k // kb) * (c // cb)
        key = (fp["mxu_fill"], -steps)
        if best is None or key > best[0]:
            best = (key, (kb, cb), fp)
    assert best is not None, "even (1,1) blocks exceed VMEM — strip the image first"
    return {"kblk": best[1][0], "cblk": best[1][1], **best[2]}


def vmem_footprint_bytes(c: int, h: int, w: int, k: int, kblk: int = 4, cblk: int | None = None) -> dict:
    """Estimate the VMEM working set of one grid step (DESIGN.md §Perf).

    Mirrors the BlockSpec tiles above: image block + weight block + bias
    block + output block, f32. Used by the perf pass to keep tiles under
    the ~16 MiB VMEM budget and by EXPERIMENTS.md §Perf.
    """
    if cblk is None:
        cblk = c // 4 if c % 4 == 0 and c >= 4 else c
    kblk = min(kblk, k)
    oh, ow = h - KH + 1, w - KW + 1
    img_b = 4 * cblk * h * w
    w_b = 4 * kblk * cblk * KH * KW
    out_b = 4 * kblk * oh * ow
    total = img_b + w_b + 4 * kblk + out_b
    # MXU utilisation proxy: contraction dim (9*cblk) and output dims
    # (oh*ow, kblk) vs the 128x128 systolic array.
    mxu_m = min(oh * ow, 128) / 128
    mxu_k = min(9 * cblk, 128) / 128
    mxu_n = min(kblk, 128) / 128
    return {
        "image_bytes": img_b,
        "weight_bytes": w_b,
        "output_bytes": out_b,
        "total_bytes": total,
        "fits_vmem_16MiB": total <= 16 * 2**20,
        "mxu_fill": mxu_m * mxu_k * mxu_n,
    }
