//! XLA/PJRT executor: compile-once, execute-many on the CPU client.
//!
//! HLO **text** is the interchange format (see `aot.py` and
//! /opt/xla-example/README.md — serialized protos from jax ≥ 0.5 are
//! rejected by xla_extension 0.5.1). Executables are compiled lazily on
//! first use and cached for the life of the runtime.

use super::artifacts::ArtifactRegistry;
use crate::model::{LayerSpec, Tensor};
use std::collections::HashMap;

/// PJRT client + compiled executable cache.
pub struct XlaRuntime {
    pub registry: ArtifactRegistry,
    client: xla::PjRtClient,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
    /// Executions performed (metrics).
    pub executions: u64,
}

impl XlaRuntime {
    pub fn new(registry: ArtifactRegistry) -> anyhow::Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(anyhow_xla)?;
        Ok(XlaRuntime {
            registry,
            client,
            cache: HashMap::new(),
            executions: 0,
        })
    }

    pub fn with_default_registry() -> anyhow::Result<Self> {
        Self::new(ArtifactRegistry::load_default()?)
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch from cache) the executable for a variant.
    fn executable(&mut self, name: &str) -> anyhow::Result<&xla::PjRtLoadedExecutable> {
        if !self.cache.contains_key(name) {
            let variant = self
                .registry
                .variants
                .get(name)
                .ok_or_else(|| anyhow::anyhow!("unknown artifact variant '{name}'"))?
                .clone();
            let path = self.registry.hlo_path(&variant);
            let proto = xla::HloModuleProto::from_text_file(&path).map_err(anyhow_xla)?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp).map_err(anyhow_xla)?;
            self.cache.insert(name.to_string(), exe);
        }
        Ok(&self.cache[name])
    }

    /// Execute a variant with f32 tensor inputs; returns the flat f32
    /// output (single tuple element, as lowered with return_tuple=True).
    pub fn execute(&mut self, name: &str, inputs: &[Tensor<f32>]) -> anyhow::Result<Tensor<f32>> {
        let variant = self
            .registry
            .variants
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("unknown artifact variant '{name}'"))?
            .clone();
        anyhow::ensure!(
            inputs.len() == variant.inputs.len(),
            "variant {name} expects {} inputs, got {}",
            variant.inputs.len(),
            inputs.len()
        );
        for (i, (t, want)) in inputs.iter().zip(&variant.inputs).enumerate() {
            anyhow::ensure!(
                t.shape() == &want[..],
                "input {i} of {name}: shape {:?} != manifest {:?}",
                t.shape(),
                want
            );
        }
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| literal_from_tensor(t))
            .collect::<anyhow::Result<_>>()?;
        let exe = self.executable(name)?;
        let result = exe.execute::<xla::Literal>(&literals).map_err(anyhow_xla)?[0][0]
            .to_literal_sync()
            .map_err(anyhow_xla)?;
        self.executions += 1;
        let out = result.to_tuple1().map_err(anyhow_xla)?;
        let values = out.to_vec::<f32>().map_err(anyhow_xla)?;
        Ok(Tensor::from_vec(&variant.output, values))
    }

    /// Run one conv layer (u8 image/weights, i32 bias → f32 carriers).
    pub fn run_layer(
        &mut self,
        spec: &LayerSpec,
        img: &Tensor<u8>,
        weights: &Tensor<u8>,
        bias: &[i32],
    ) -> anyhow::Result<Tensor<f32>> {
        let name = spec.name();
        let b = Tensor::from_vec(&[bias.len()], bias.iter().map(|&v| v as f32).collect());
        self.execute(&name, &[img.to_f32(), weights.to_f32(), b])
    }

    /// Run the fused edge CNN artifact: image + (w, b) per layer.
    pub fn run_edge_cnn(
        &mut self,
        img: &Tensor<u8>,
        params: &[(Tensor<u8>, Vec<i32>)],
    ) -> anyhow::Result<Vec<f32>> {
        let mut inputs = vec![img.to_f32()];
        for (w, b) in params {
            inputs.push(w.to_f32());
            inputs.push(Tensor::from_vec(
                &[b.len()],
                b.iter().map(|&v| v as f32).collect(),
            ));
        }
        Ok(self.execute("edge_cnn", &inputs)?.into_data())
    }

    pub fn compiled_count(&self) -> usize {
        self.cache.len()
    }
}

fn literal_from_tensor(t: &Tensor<f32>) -> anyhow::Result<xla::Literal> {
    let dims: Vec<i64> = t.shape().iter().map(|&d| d as i64).collect();
    xla::Literal::vec1(t.data())
        .reshape(&dims)
        .map_err(anyhow_xla)
}

fn anyhow_xla(e: xla::Error) -> anyhow::Error {
    anyhow::anyhow!("xla: {e}")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{golden, QUICKSTART};
    use crate::util::prng::Prng;

    fn runtime() -> Option<XlaRuntime> {
        XlaRuntime::with_default_registry().ok()
    }

    #[test]
    fn quickstart_layer_matches_golden() {
        let Some(mut rt) = runtime() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let spec = QUICKSTART;
        let mut rng = Prng::new(77);
        let img = Tensor::from_vec(&[spec.c, spec.h, spec.w], rng.bytes_below(spec.c * spec.h * spec.w, 128));
        let wts = Tensor::from_vec(&[spec.k, spec.c, 3, 3], rng.bytes_below(spec.k * spec.c * 9, 64));
        let bias: Vec<i32> = (0..spec.k).map(|_| rng.range_i64(-50, 50) as i32).collect();
        let out = rt.run_layer(&spec, &img, &wts, &bias).unwrap();
        let want = golden::conv3x3_i32(&img, &wts, &bias, spec.relu);
        assert_eq!(out.shape(), want.shape());
        for (a, b) in out.data().iter().zip(want.data()) {
            assert_eq!(*a, *b as f32);
        }
    }

    #[test]
    fn executable_cache_reuses_compilations() {
        let Some(mut rt) = runtime() else {
            return;
        };
        let spec = QUICKSTART;
        let mut rng = Prng::new(78);
        let img = Tensor::from_vec(&[spec.c, spec.h, spec.w], rng.bytes_below(spec.c * spec.h * spec.w, 128));
        let wts = Tensor::from_vec(&[spec.k, spec.c, 3, 3], rng.bytes_below(spec.k * spec.c * 9, 64));
        let bias = vec![0i32; spec.k];
        rt.run_layer(&spec, &img, &wts, &bias).unwrap();
        rt.run_layer(&spec, &img, &wts, &bias).unwrap();
        assert_eq!(rt.compiled_count(), 1);
        assert_eq!(rt.executions, 2);
    }

    #[test]
    fn shape_mismatch_is_rejected() {
        let Some(mut rt) = runtime() else {
            return;
        };
        let bad = Tensor::<f32>::zeros(&[1, 2, 3]);
        assert!(rt
            .execute(&QUICKSTART.name(), &[bad.clone(), bad.clone(), bad])
            .is_err());
    }
}
