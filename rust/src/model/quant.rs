//! Inter-layer requantisation: i32 accumulator → u8 activations.
//!
//! The paper's core consumes and produces 8-bit entries (Fig. 6), which
//! implies the surrounding system requantises every layer's wide
//! accumulator output back to 8 bits before it becomes the next layer's
//! input. The paper leaves that step to the PS; we implement the
//! standard power-of-two rescale an edge deployment would use, so the
//! simulated hardware pipeline can chain layers exactly like §4.1's
//! output-BRAMs-feed-the-next-layer scheme.

use super::tensor::Tensor;

/// Power-of-two requantisation parameters for one layer boundary.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Requant {
    /// Right-shift applied to the i32 accumulator (rounding toward -inf).
    pub shift: u32,
    /// Clamp ceiling after shift (255 for full u8).
    pub max: u8,
}

impl Requant {
    pub fn new(shift: u32) -> Self {
        Requant { shift, max: 255 }
    }

    /// Choose a shift so the observed accumulator maximum lands in u8
    /// range — what a calibration pass over sample data produces.
    pub fn calibrate(acc_max: i32) -> Self {
        let mut shift = 0u32;
        let mut v = acc_max.max(1);
        while v > 255 {
            v >>= 1;
            shift += 1;
        }
        Requant { shift, max: 255 }
    }

    #[inline]
    pub fn apply_scalar(&self, v: i32) -> u8 {
        let shifted = v >> self.shift;
        shifted.clamp(0, self.max as i32) as u8
    }

    /// Requantise a whole feature map.
    pub fn apply(&self, t: &Tensor<i32>) -> Tensor<u8> {
        t.map(|v| self.apply_scalar(v))
    }
}

/// Calibrate from an actual tensor (max over data, ReLU-style floor at 0).
pub fn calibrate_from(t: &Tensor<i32>) -> Requant {
    Requant::calibrate(t.data().iter().copied().max().unwrap_or(0))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn calibrated_output_fits_u8() {
        for acc_max in [1, 100, 255, 256, 1000, 123_456, i32::MAX] {
            let q = Requant::calibrate(acc_max);
            let _fits_in_u8: u8 = q.apply_scalar(acc_max); // type proves <= 255
            // The top of the range must not collapse to zero (information
            // preserved up to the shift).
            assert!(q.apply_scalar(acc_max) >= 128 || acc_max < 128);
        }
    }

    #[test]
    fn zero_shift_is_clamp() {
        let q = Requant::new(0);
        assert_eq!(q.apply_scalar(-5), 0);
        assert_eq!(q.apply_scalar(0), 0);
        assert_eq!(q.apply_scalar(200), 200);
        assert_eq!(q.apply_scalar(300), 255);
    }

    #[test]
    fn shift_divides() {
        let q = Requant::new(4);
        assert_eq!(q.apply_scalar(160), 10);
        assert_eq!(q.apply_scalar(255), 15);
    }

    #[test]
    fn apply_maps_whole_tensor() {
        let t = Tensor::from_vec(&[1, 2, 2], vec![-1, 0, 256, 1024]);
        let q = Requant::new(2);
        assert_eq!(q.apply(&t).data(), &[0, 0, 64, 255]);
    }

    #[test]
    fn monotone() {
        let q = Requant::calibrate(100_000);
        let mut prev = 0u8;
        for v in (0..100_000).step_by(997) {
            let cur = q.apply_scalar(v);
            assert!(cur >= prev);
            prev = cur;
        }
    }
}
