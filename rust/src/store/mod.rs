//! Content-addressed storage for the serving stack.
//!
//! One resident today: [`WeightStore`], the per-peer LRU of weight
//! blobs keyed by byte-hash that backs wire protocol v4's
//! ship-on-miss path (`coordinator/tcp.rs` owns one per `TcpServer`;
//! the framing grammar lives in that module's doc). Capacity is not an
//! arbitrary byte budget: it is priced by the board's BRAM model
//! (`hw/capacity.rs`), because the blobs a peer keeps warm are exactly
//! the weights §4.1's BMG organisation would hold resident on-chip.

pub mod weightstore;

pub use weightstore::WeightStore;
