//! PCORE (§4.2): one partial-sum engine — 9 MAC units + adder tree.
//!
//! A PCORE holds the 9 weights of *one channel of one kernel* (delivered
//! by the Weight Loader, where they stay resident — weight stationary)
//! and, each compute step, consumes the 9-value image window the Image
//! Loader broadcasts to all four PCOREs of its computing core, emitting
//! one PSUM.

use super::mac::{dot9_i32, dot9_wrap8};
use super::AccumMode;

/// PSUM value in either accumulator width.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Psum {
    Wrap8(u8),
    I32(i32),
}

impl Psum {
    pub fn as_i64(self) -> i64 {
        match self {
            Psum::Wrap8(v) => v as i64,
            Psum::I32(v) => v as i64,
        }
    }
}

/// One PCORE: weight register file + the MAC/adder datapath.
#[derive(Clone, Debug)]
pub struct PCore {
    /// Resident weights (one kernel-channel, row-major 3x3).
    weights: [u8; 9],
    /// PSUMs produced (per-layer stat).
    pub psum_count: u64,
}

impl Default for PCore {
    fn default() -> Self {
        Self::new()
    }
}

impl PCore {
    pub fn new() -> Self {
        PCore {
            weights: [0; 9],
            psum_count: 0,
        }
    }

    /// Weight Loader writes a new kernel-channel into the register file.
    pub fn load_weights(&mut self, w: [u8; 9]) {
        self.weights = w;
    }

    pub fn weights(&self) -> [u8; 9] {
        self.weights
    }

    /// One compute step: 9 MACs + adder tree over the broadcast window.
    #[inline]
    pub fn compute(&mut self, window: &[u8; 9], mode: AccumMode) -> Psum {
        self.psum_count += 1;
        match mode {
            AccumMode::Wrap8 => Psum::Wrap8(dot9_wrap8(window, &self.weights)),
            AccumMode::I32 => Psum::I32(dot9_i32(window, &self.weights)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn weight_stationarity() {
        let mut p = PCore::new();
        p.load_weights([1, 2, 3, 4, 5, 6, 7, 8, 9]);
        let w_before = p.weights();
        let _ = p.compute(&[9; 9], AccumMode::I32);
        let _ = p.compute(&[3; 9], AccumMode::Wrap8);
        assert_eq!(p.weights(), w_before, "compute must not disturb weights");
        assert_eq!(p.psum_count, 2);
    }

    #[test]
    fn computes_fig6_psum() {
        let mut p = PCore::new();
        p.load_weights([0x91, 0x92, 0x93, 0x94, 0x95, 0x96, 0x97, 0x98, 0x99]);
        let win = [0x01, 0x02, 0x03, 0x06, 0x07, 0x08, 0x0b, 0x0c, 0x0d];
        assert_eq!(p.compute(&win, AccumMode::Wrap8), Psum::Wrap8(0x0b));
    }

    #[test]
    fn wide_mode_matches_manual_dot() {
        let mut p = PCore::new();
        p.load_weights([10, 0, 0, 0, 0, 0, 0, 0, 20]);
        let win = [5, 0, 0, 0, 0, 0, 0, 0, 7];
        assert_eq!(p.compute(&win, AccumMode::I32), Psum::I32(50 + 140));
    }
}
