//! Bench: experiment S52 + F6 — the IP-core simulator itself.
//!
//! Reports (a) simulated-hardware figures (cycles, GOPS at 112 MHz) for
//! the paper's §5.2 workload and the Fig. 6 testbench, and (b) the
//! host-side speed of the simulator (simulated MACs per host second),
//! which is what the §Perf pass optimises.

use repro::bench_util::{black_box, Bencher};
use repro::hw::ip_core::{gops_mac, gops_psum};
use repro::hw::waveform::fig6_stimulus;
use repro::hw::{AccumMode, IpCore, IpCoreConfig};
use repro::model::{LayerSpec, Tensor, QUICKSTART, S52};
use repro::paper::FREQ_Z2_HZ;
use repro::util::prng::Prng;

fn inputs(spec: &LayerSpec, seed: u64) -> (Tensor<u8>, Tensor<u8>, Vec<i32>) {
    let mut rng = Prng::new(seed);
    (
        Tensor::from_vec(
            &[spec.c, spec.h, spec.w],
            rng.bytes_below(spec.c * spec.h * spec.w, 256),
        ),
        Tensor::from_vec(&[spec.k, spec.c, 3, 3], rng.bytes_below(spec.k * spec.c * 9, 256)),
        vec![0i32; spec.k],
    )
}

fn main() {
    println!("=== bench: ipcore (experiments S52, F6) ===");
    let b = Bencher::default();

    // --- Fig. 6 testbench (tiny; shows per-layer overhead floor).
    {
        let (spec, img, wts, bias) = fig6_stimulus();
        let mut core = IpCore::new(IpCoreConfig {
            mode: AccumMode::Wrap8,
            ..Default::default()
        });
        b.run_throughput("fig6_testbench (36 psums)", spec.psums() as f64, || {
            black_box(core.run_layer(&spec, &img, &wts, &bias, None).unwrap())
        });
    }

    // --- quickstart layer.
    {
        let spec = QUICKSTART;
        let (img, wts, bias) = inputs(&spec, 1);
        let mut core = IpCore::new(IpCoreConfig::default());
        let run = core.run_layer(&spec, &img, &wts, &bias, None).unwrap();
        println!(
            "  sim: {} compute cycles -> {:.4} GOPS(psum) @112MHz",
            run.cycles.compute,
            gops_psum(spec.psums(), run.cycles.compute, FREQ_Z2_HZ)
        );
        b.run_throughput(
            "quickstart 8x16x16 k8 (sim MACs/s)",
            spec.macs() as f64,
            || black_box(core.run_layer(&spec, &img, &wts, &bias, None).unwrap()),
        );
    }

    // --- the §5.2 headline workload.
    {
        let spec = S52;
        let (img, wts, bias) = inputs(&spec, 52);
        let mut core = IpCore::new(IpCoreConfig::default());
        let run = core.run_layer(&spec, &img, &wts, &bias, None).unwrap();
        println!(
            "  sim: {} compute cycles = {:.5}s @112MHz -> {:.4} GOPS(psum) {:.3} GOPS(mac) [paper: 1,577,088 / 0.01408s / 0.224]",
            run.cycles.compute,
            run.cycles.compute as f64 / FREQ_Z2_HZ as f64,
            gops_psum(spec.psums(), run.cycles.compute, FREQ_Z2_HZ),
            gops_mac(spec.psums(), run.cycles.compute, FREQ_Z2_HZ)
        );
        let slow = Bencher {
            budget: std::time::Duration::from_secs(4),
            warmup: std::time::Duration::from_millis(200),
            max_iters: 20,
        };
        slow.run_throughput("s52 224x224x8 k8 (sim MACs/s)", spec.macs() as f64, || {
            black_box(core.run_layer(&spec, &img, &wts, &bias, None).unwrap())
        });
    }

    // --- wrap8 vs i32 accumulator cost on the host.
    {
        let spec = QUICKSTART;
        let (img, wts, bias) = inputs(&spec, 3);
        let mut w8 = IpCore::new(IpCoreConfig {
            mode: AccumMode::Wrap8,
            ..Default::default()
        });
        b.run("quickstart wrap8 accumulator", || {
            black_box(w8.run_layer(&spec, &img, &wts, &bias, None).unwrap())
        });
    }
}
