//! Unified backend parity harness: ONE property suite, run over every
//! `ConvBackend` the build can construct — the cycle-accurate simulator,
//! the naive golden fallback, the threaded im2col+GEMM backend at
//! several thread counts, TWO `RemoteBackend`s over real sockets — one
//! to an in-process wire-protocol-v4 server (binary tensor frames +
//! content-addressed weight store) and one to a v2-pinned server
//! (legacy JSON tensors, exercising the front's negotiation fallback)
//! — and (when the runtime is linked and artifacts exist) the XLA
//! path. A registry leg submits (model, layer) jobs from the
//! multi-model registry through both remotes twice, so the second
//! round rides the v4 weight store hash-only and must stay bit-exact. For identical integer inputs every
//! backend must produce **bit-identical** i32 outputs across
//! randomized specs, all three job kinds (standard, depthwise,
//! pointwise-as-3×3) and both accumulator modes (wrap-8 silicon vs
//! production I32). For the remote legs that parity is end-to-end: the
//! tensors cross the wire both ways, in both framings.
//!
//! Each case asks every backend whether it `allows` the (spec, kind,
//! accum) triple — exactly the dispatcher's routing predicate — so a
//! backend that declines a job is skipped the same way the pool would
//! skip it, and a backend that *claims* a job is held to the reference.
//!
//! In-tree PRNG harness (no proptest offline): every case reports its
//! seed so failures reproduce exactly.

use repro::backend::{
    ConvBackend, GoldenBackend, Im2colBackend, JobKind, JobPayload, RemoteBackend, SimBackend,
    XlaBackend,
};
use repro::coordinator::tcp::TcpServer;
use repro::coordinator::CoordinatorConfig;
use repro::hw::depthwise::{golden_depthwise3x3, golden_pointwise, pad1, pointwise_as_3x3};
use repro::hw::{AccumMode, IpCoreConfig};
use repro::model::{golden, LayerSpec, Tensor};
use repro::util::prng::Prng;

/// The backend set under test, plus the in-process TCP servers the
/// remote legs dial (kept alive for the suite, stopped at the end).
struct Fleet {
    backends: Vec<Box<dyn ConvBackend>>,
    servers: Vec<TcpServer>,
}

impl Fleet {
    fn stop(&mut self) {
        // Drop the backends first so the remote connections close and
        // the servers' handlers drain on EOF.
        self.backends.clear();
        for server in self.servers.drain(..) {
            server.stop();
        }
    }
}

/// Every backend the suite can construct offline, in I32 (production)
/// mode. XLA joins when the feature is linked and artifacts exist; its
/// spec allowlist keeps it out of cases it never compiled. The remote
/// legs run against real sockets: an in-process v4 server (binary
/// tensor frames + weight store) fronting a small heterogeneous pool
/// (2 sim cores + 1 im2col worker), and a v2-pinned server the front
/// must serve over legacy JSON tensors — same properties, both
/// framings.
fn all_backends() -> Fleet {
    let mut v: Vec<Box<dyn ConvBackend>> = vec![
        Box::new(SimBackend::new(IpCoreConfig::default())),
        Box::new(GoldenBackend::new()),
        Box::new(Im2colBackend::new(1)),
        Box::new(Im2colBackend::new(4)),
    ];
    match XlaBackend::try_new() {
        Ok(b) => v.push(Box::new(b)),
        Err(e) => eprintln!("parity harness runs without the xla leg: {e}"),
    }
    let v4 = TcpServer::start(
        "127.0.0.1:0",
        CoordinatorConfig::default().with_cores(2).with_im2col_workers(1),
    )
    .expect("in-process wire-v4 server for the remote leg");
    let v2 = TcpServer::start(
        "127.0.0.1:0",
        CoordinatorConfig::default().with_cores(2).with_wire_v2_only(),
    )
    .expect("in-process v2-pinned server for the legacy remote leg");
    let remote_v4 = RemoteBackend::connect(&v4.addr.to_string())
        .expect("remote backend handshake (v4)");
    assert!(
        remote_v4.peer_binary(),
        "v4 server must negotiate binary frames"
    );
    assert!(
        remote_v4.peer_wcache(),
        "v4 server must negotiate the weight store"
    );
    let remote_v2 = RemoteBackend::connect(&v2.addr.to_string())
        .expect("remote backend handshake (v2 fallback)");
    assert!(
        !remote_v2.peer_binary(),
        "v2-pinned server must stay on JSON tensors"
    );
    assert!(
        !remote_v2.peer_wcache(),
        "v2-pinned server must not advertise the weight store"
    );
    v.push(Box::new(remote_v4));
    v.push(Box::new(remote_v2));
    Fleet {
        backends: v,
        servers: vec![v4, v2],
    }
}

/// Run `payload` on every backend that claims it (the dispatcher's own
/// `allows` predicate) and assert each result is bit-identical to
/// `want`. Returns how many backends ran, so callers can assert the
/// suite exercised what it meant to.
fn assert_parity(
    backends: &mut [Box<dyn ConvBackend>],
    payload: &JobPayload,
    accum: AccumMode,
    want: &Tensor<i32>,
    label: &str,
) -> usize {
    let mut ran = 0;
    for be in backends.iter_mut() {
        if !be.capability().allows(payload.spec, payload.kind, accum) {
            continue;
        }
        let name = be.name();
        let run = be
            .run(payload)
            .unwrap_or_else(|e| panic!("{label}: backend {name} claimed the job but failed: {e}"));
        assert_eq!(
            run.output.data(),
            want.data(),
            "{label}: {name} diverges from the reference"
        );
        ran += 1;
    }
    ran
}

/// Random paper-compatible raw-conv spec (no relu/pool: the backend
/// contract is the raw accumulator output for standard jobs).
fn arb_spec(rng: &mut Prng) -> LayerSpec {
    let c = *rng.choose(&[1usize, 2, 3, 4, 5, 8, 12, 16]);
    let k = *rng.choose(&[4usize, 8, 12, 16]);
    let h = 3 + rng.below(10) as usize;
    let w = 3 + rng.below(10) as usize;
    LayerSpec::new(c, h, w, k)
}

fn arb_case(rng: &mut Prng, spec: &LayerSpec) -> (Tensor<u8>, Tensor<u8>, Vec<i32>) {
    (
        Tensor::from_vec(
            &[spec.c, spec.h, spec.w],
            rng.bytes_below(spec.c * spec.h * spec.w, 256),
        ),
        Tensor::from_vec(
            &[spec.k, spec.c, 3, 3],
            rng.bytes_below(spec.k * spec.c * 9, 256),
        ),
        (0..spec.k).map(|_| rng.range_i64(-100, 100) as i32).collect(),
    )
}

#[test]
fn prop_standard_jobs_agree_across_all_backends() {
    let mut fleet = all_backends();
    for seed in 0..50u64 {
        let mut rng = Prng::new(seed);
        let spec = arb_spec(&mut rng);
        let (img, wts, bias) = arb_case(&mut rng, &spec);
        let want = golden::conv3x3_i32(&img, &wts, &bias, false);
        let payload = JobPayload {
            kind: JobKind::Standard,
            spec: &spec,
            img: &img,
            weights: &wts,
            bias: &bias,
            weights_resident: false,
            trace_id: 0,
        };
        let ran = assert_parity(&mut fleet.backends, &payload, AccumMode::I32, &want, &format!("seed {seed} spec {spec:?}"));
        // sim + golden + im2col×2 + remote×2 (v4 + v2 fallback) at
        // minimum (xla only on its own specs).
        assert!(ran >= 6, "seed {seed}: only {ran} backends ran");
    }
    fleet.stop();
}

#[test]
fn prop_depthwise_jobs_agree_across_all_backends() {
    let mut fleet = all_backends();
    for seed in 100..140u64 {
        let mut rng = Prng::new(seed);
        let c = *rng.choose(&[1usize, 3, 4, 8, 16]);
        let h = 3 + rng.below(10) as usize;
        let w = 3 + rng.below(10) as usize;
        let mut spec = LayerSpec::new(c, h, w, c);
        if rng.f64() < 0.5 {
            // Depthwise fuses ReLU on the backend (the core's entry
            // point does); cover both settings.
            spec = spec.with_relu();
        }
        let img = Tensor::from_vec(&[c, h, w], rng.bytes_below(c * h * w, 256));
        let wts = Tensor::from_vec(&[c, 3, 3], rng.bytes_below(c * 9, 256));
        let bias: Vec<i32> = (0..c).map(|_| rng.range_i64(-100, 100) as i32).collect();
        let want = golden_depthwise3x3(&img, &wts, &bias, spec.relu);
        let payload = JobPayload {
            kind: JobKind::Depthwise,
            spec: &spec,
            img: &img,
            weights: &wts,
            bias: &bias,
            weights_resident: false,
            trace_id: 0,
        };
        let ran = assert_parity(&mut fleet.backends, &payload, AccumMode::I32, &want, &format!("seed {seed} c={c} h={h} w={w} relu={}", spec.relu));
        assert!(ran >= 6, "seed {seed}: only {ran} backends ran depthwise");
    }
    fleet.stop();
}

#[test]
fn prop_pointwise_as_3x3_jobs_agree_across_all_backends_and_reference() {
    let mut fleet = all_backends();
    for seed in 200..230u64 {
        let mut rng = Prng::new(seed);
        let c = *rng.choose(&[2usize, 4, 8]);
        let k = *rng.choose(&[4usize, 8]);
        let h = 3 + rng.below(8) as usize;
        let w = 3 + rng.below(8) as usize;
        let img = Tensor::from_vec(&[c, h, w], rng.bytes_below(c * h * w, 256));
        let w1x1 = Tensor::from_vec(&[k, c], rng.bytes_below(k * c, 256));
        let bias: Vec<i32> = (0..k).map(|_| rng.range_i64(-50, 50) as i32).collect();

        // Lower 1x1 -> padded 3x3, the IP core's dataflow. The direct
        // 1x1 reference anchors the whole lowering, not just parity.
        let padded = pad1(&img);
        let w3 = pointwise_as_3x3(&w1x1);
        let spec = LayerSpec::new(c, h + 2, w + 2, k);
        let want = golden_pointwise(&img, &w1x1, &bias);

        let payload = JobPayload {
            kind: JobKind::PointwiseAs3x3,
            spec: &spec,
            img: &padded,
            weights: &w3,
            bias: &bias,
            weights_resident: false,
            trace_id: 0,
        };
        let ran = assert_parity(&mut fleet.backends, &payload, AccumMode::I32, &want, &format!("seed {seed}: vs direct 1x1"));
        assert!(ran >= 6, "seed {seed}: only {ran} backends ran pointwise");
    }
    fleet.stop();
}

#[test]
fn prop_wrap8_jobs_route_only_to_wrap8_silicon_and_match_reference() {
    // The other accumulator mode: a wrap-8 job must be declined by every
    // I32 backend — including the remote leg, whose wire serves I32
    // production traffic only (exactly what the dispatcher's accum mask
    // enforces) — and served bit-exactly by the wrap-8 core: widened
    // mod-256 values of the conv3x3_wrap8 reference.
    let mut fleet = all_backends();
    let mut wrap8 = SimBackend::new(IpCoreConfig {
        mode: AccumMode::Wrap8,
        ..Default::default()
    });
    for seed in 300..330u64 {
        let mut rng = Prng::new(seed);
        let spec = arb_spec(&mut rng);
        let (img, wts, _) = arb_case(&mut rng, &spec);
        // Wrap-8 bias preloads the 8-bit accumulator: keep it in u8 range.
        let bias8: Vec<u8> = (0..spec.k).map(|_| rng.below(256) as u8).collect();
        let bias: Vec<i32> = bias8.iter().map(|&b| b as i32).collect();
        let payload = JobPayload {
            kind: JobKind::Standard,
            spec: &spec,
            img: &img,
            weights: &wts,
            bias: &bias,
            weights_resident: false,
            trace_id: 0,
        };

        for be in fleet.backends.iter_mut() {
            assert!(
                !be.capability().allows(&spec, JobKind::Standard, AccumMode::Wrap8),
                "seed {seed}: {} must decline wrap8 traffic",
                be.name()
            );
        }
        assert!(wrap8.capability().allows(&spec, JobKind::Standard, AccumMode::Wrap8));
        assert!(!wrap8.capability().allows(&spec, JobKind::Standard, AccumMode::I32));

        let run = wrap8.run(&payload).unwrap_or_else(|e| panic!("seed {seed}: {e}"));
        let want = golden::conv3x3_wrap8(&img, &wts, &bias8).map(|v| v as i32);
        assert_eq!(run.output.data(), want.data(), "seed {seed} spec {spec:?}");
    }
    fleet.stop();
}

#[test]
fn xla_backend_agrees_when_available() {
    let mut xla = match XlaBackend::try_new() {
        Ok(b) => b,
        Err(e) => {
            eprintln!("skipping xla parity leg (feature off or artifacts missing): {e}");
            return;
        }
    };
    let specs = xla.served_specs();
    assert!(!specs.is_empty(), "linked runtime must serve raw-conv specs");
    let mut others: Vec<Box<dyn ConvBackend>> = vec![
        Box::new(SimBackend::new(IpCoreConfig::default())),
        Box::new(GoldenBackend::new()),
        Box::new(Im2colBackend::new(4)),
    ];
    for (i, spec) in specs.iter().enumerate() {
        if spec.h > 64 {
            continue; // S52-sized shapes have their own test elsewhere
        }
        let mut rng = Prng::new(3000 + i as u64);
        let img = Tensor::from_vec(
            &[spec.c, spec.h, spec.w],
            rng.bytes_below(spec.c * spec.h * spec.w, 128),
        );
        let wts = Tensor::from_vec(
            &[spec.k, spec.c, 3, 3],
            rng.bytes_below(spec.k * spec.c * 9, 32),
        );
        let bias: Vec<i32> = (0..spec.k).map(|_| rng.range_i64(-20, 20) as i32).collect();
        let payload = JobPayload {
            kind: JobKind::Standard,
            spec,
            img: &img,
            weights: &wts,
            bias: &bias,
            weights_resident: false,
            trace_id: 0,
        };
        let want = golden::conv3x3_i32(&img, &wts, &bias, false);
        let from_xla = xla.run(&payload).unwrap();
        assert_eq!(from_xla.output.data(), want.data(), "{}: xla vs golden", spec.name());
        assert_parity(&mut others, &payload, AccumMode::I32, &want, &spec.name());
    }
}

#[test]
fn registry_submissions_are_bit_identical_across_v4_and_v2_remotes() {
    // The multi-model registry leg: every (model, layer) submission is
    // run twice through a v4 remote (second round goes hash-only over
    // the weight store) and twice through a v2-pinned remote (inline
    // JSON tensors both times, never a v4 frame), and each result must
    // be bit-identical to the local golden reference.
    use repro::registry::ModelRegistry;
    use std::sync::atomic::Ordering;

    let v4 = TcpServer::start("127.0.0.1:0", CoordinatorConfig::default().with_cores(2))
        .expect("in-process v4 server");
    let v2 = TcpServer::start(
        "127.0.0.1:0",
        CoordinatorConfig::default().with_cores(2).with_wire_v2_only(),
    )
    .expect("in-process v2-pinned server");
    let mut remote_v4 =
        RemoteBackend::connect(&v4.addr.to_string()).expect("remote handshake (v4)");
    let mut remote_v2 =
        RemoteBackend::connect(&v2.addr.to_string()).expect("remote handshake (v2)");
    assert!(remote_v4.peer_wcache());
    assert!(!remote_v2.peer_wcache());
    let mut reference = GoldenBackend::new();

    let registry = ModelRegistry::builtin(3, 7);
    let mut id = 0u64;
    for m in 0..registry.n_models() {
        for l in 0..registry.n_layers(m) {
            for round in 0..2u64 {
                let job = registry
                    .job(m, l, id, 0x9e37 ^ (id << 3) ^ round)
                    .expect("in-range (model, layer)");
                id += 1;
                let payload = job.payload(false);
                let want = reference.run(&payload).expect("golden reference").output;
                let got4 = remote_v4.run(&payload).expect("v4 remote").output;
                let got2 = remote_v2.run(&payload).expect("v2 remote").output;
                assert_eq!(
                    got4.data(),
                    want.data(),
                    "model {m} layer {l} round {round}: v4 remote diverges"
                );
                assert_eq!(
                    got2.data(),
                    want.data(),
                    "model {m} layer {l} round {round}: v2 remote diverges"
                );
            }
        }
    }

    // The v4 peer cached repeated blobs; the v2-pinned peer never saw
    // any v4 cache traffic, on either side of its connection.
    assert!(v4.metrics().weight_hits.load(Ordering::Relaxed) > 0);
    assert_eq!(v2.metrics().weight_hits.load(Ordering::Relaxed), 0);
    assert_eq!(v2.metrics().weight_misses.load(Ordering::Relaxed), 0);
    let v2_known = remote_v2.known_weights().expect("client-side cache stats");
    assert!(v2_known.is_empty(), "v2 connection must not track weight hashes");
    assert_eq!(v2_known.stats(), (0, 0, 0));

    drop(remote_v4);
    drop(remote_v2);
    v4.stop();
    v2.stop();
}

#[test]
fn streaming_inference_is_bit_exact_across_a_mixed_protocol_fleet() {
    // The whole-network streaming leg: images walked layer-by-layer
    // across a mixed v4 / v2-pinned fleet must come back with logits
    // bit-identical to the manifest's own golden forward, per image —
    // layer hops land on whichever peer dispatch picks, boundary
    // transforms run on the front, and the wire framing (binary + weight
    // store vs legacy JSON) must never leak into the numerics.
    use repro::coordinator::Server;
    use repro::registry::ModelRegistry;
    use std::sync::atomic::Ordering;

    let v4 = TcpServer::start("127.0.0.1:0", CoordinatorConfig::default().with_cores(2))
        .expect("v4 peer");
    let v2 = TcpServer::start(
        "127.0.0.1:0",
        CoordinatorConfig::default().with_cores(2).with_wire_v2_only(),
    )
    .expect("v2-pinned peer");
    let cfg = CoordinatorConfig {
        n_cores: 0,
        ..CoordinatorConfig::default()
            .with_remote_peers(vec![v4.addr.to_string(), v2.addr.to_string()])
            .with_stream_window(4)
    };
    let mut front = Server::try_new(cfg).expect("front dials both peers");
    let registry = ModelRegistry::builtin(2, 23);
    let n = 8;
    let seed = 31u64;
    let (report, outcome) = front.run_stream_trace(&registry, n, seed, &mut |_| {});
    assert_eq!(report.n_images, n);
    assert_eq!(report.n_errors, 0, "{report:?}");
    assert_eq!(outcome.images.len(), n);
    for o in &outcome.images {
        assert_eq!(o.model, o.image % registry.n_models());
        // Recompute the reference independently of the scheduler's own
        // bookkeeping: the manifest golden over the same derived input.
        let manifest = &registry.models()[o.model];
        let want = manifest
            .forward_golden(&manifest.sample_image(seed ^ ((o.image as u64) << 1)))
            .into_data();
        assert_eq!(
            o.logits, want,
            "image {}: streamed logits diverge from forward_golden",
            o.image
        );
        assert!(o.matches && o.error.is_none());
    }
    assert!(outcome.overlap_events > 0, "stream never overlapped images");
    // Both framings served layer hops, the v4 store saw repeat blobs,
    // and the v2-pinned peer stayed cache-silent throughout.
    assert!(
        outcome.backend_mix.len() >= 2,
        "both peers must serve hops: {:?}",
        outcome.backend_mix
    );
    assert!(
        report.n_weight_hits > 0,
        "repeat images must ride the v4 weight store: {report:?}"
    );
    assert_eq!(v2.metrics().weight_hits.load(Ordering::Relaxed), 0);
    assert_eq!(v2.metrics().weight_misses.load(Ordering::Relaxed), 0);
    front.shutdown();
    v4.stop();
    v2.stop();
}

#[test]
fn traced_traffic_stays_bit_identical_across_a_mixed_fleet() {
    // Telemetry must be observability only. With tracing enabled end to
    // end, every output stays bit-identical to the golden reference;
    // the v4 peer answers traced requests with its server-side timing
    // split, while the v2-pinned peer — which negotiated no trace flag
    // and (per the wire tests) never receives a trace field — provably
    // cannot serve timing back.
    use repro::coordinator::Server;
    use repro::registry::ModelRegistry;
    use repro::telemetry::{validate_coverage, SpanSink};
    use std::sync::Arc;

    let v4 = TcpServer::start("127.0.0.1:0", CoordinatorConfig::default().with_cores(2))
        .expect("v4 peer");
    let v2 = TcpServer::start(
        "127.0.0.1:0",
        CoordinatorConfig::default().with_cores(2).with_wire_v2_only(),
    )
    .expect("v2-pinned peer");

    // Direct remote legs: traced payloads, bit-exact over both framings.
    let mut remote_v4 = RemoteBackend::connect(&v4.addr.to_string()).expect("v4 handshake");
    let mut remote_v2 = RemoteBackend::connect(&v2.addr.to_string()).expect("v2 handshake");
    assert!(remote_v4.peer_trace(), "v4 peer negotiates trace propagation");
    assert!(!remote_v2.peer_trace(), "v2-pinned peer must not negotiate tracing");
    let mut reference = GoldenBackend::new();
    for seed in 400..420u64 {
        let mut rng = Prng::new(seed);
        let spec = arb_spec(&mut rng);
        let (img, wts, bias) = arb_case(&mut rng, &spec);
        let payload = JobPayload {
            kind: JobKind::Standard,
            spec: &spec,
            img: &img,
            weights: &wts,
            bias: &bias,
            weights_resident: false,
            trace_id: seed,
        };
        let want = reference.run(&payload).expect("golden reference").output;
        let got4 = remote_v4.run(&payload).expect("traced v4 remote");
        let got2 = remote_v2.run(&payload).expect("traced v2 remote");
        assert_eq!(got4.output.data(), want.data(), "seed {seed}: traced v4 diverges");
        assert_eq!(got2.output.data(), want.data(), "seed {seed}: traced v2 diverges");
        assert!(
            got4.wire.is_some(),
            "seed {seed}: traced v4 reply must decompose the round trip"
        );
        assert!(
            got2.wire.is_none(),
            "seed {seed}: a v2 peer never saw the id, so it cannot time it"
        );
    }
    drop(remote_v4);
    drop(remote_v2);

    // Whole-fleet leg: a traced streaming front over both peers. Every
    // image must stay bit-identical to the manifest golden while the
    // sink collects one complete worker-tagged span tree per image.
    let sink = Arc::new(SpanSink::new());
    let cfg = CoordinatorConfig {
        n_cores: 0,
        ..CoordinatorConfig::default()
            .with_remote_peers(vec![v4.addr.to_string(), v2.addr.to_string()])
            .with_stream_window(3)
            .with_trace(Arc::clone(&sink))
    };
    let mut front = Server::try_new(cfg).expect("front dials both peers");
    let registry = ModelRegistry::builtin(2, 23);
    let (report, outcome) = front.run_stream_trace(&registry, 6, 23, &mut |_| {});
    assert_eq!(report.n_errors, 0, "{report:?}");
    assert!(
        outcome.all_match(),
        "tracing changed numerics: {:?}",
        outcome.images
    );
    let spans = sink.snapshot();
    let check = validate_coverage(&spans).expect("complete traced trees over the mixed fleet");
    assert_eq!(check.roots, 6, "one Request root per streamed image");
    assert!(
        spans.iter().any(|s| s
            .worker
            .as_deref()
            .map_or(false, |w| w.starts_with("remote@"))),
        "dispatch hops must be worker-tagged with the serving peer"
    );
    front.shutdown();
    v4.stop();
    v2.stop();
}

#[test]
fn capability_masks_are_honest() {
    // A backend that claims a kind must run it; one that declines must
    // refuse at run() too (so routing bugs fail loudly, not wrongly).
    let spec = LayerSpec::new(4, 6, 6, 4);
    let img = Tensor::<u8>::zeros(&[4, 6, 6]);
    let dw_wts = Tensor::<u8>::zeros(&[4, 3, 3]);
    let bias = vec![0i32; 4];
    let payload = JobPayload {
        kind: JobKind::Depthwise,
        spec: &spec,
        img: &img,
        weights: &dw_wts,
        bias: &bias,
        weights_resident: false,
        trace_id: 0,
    };

    for mut capable in [
        Box::new(SimBackend::new(IpCoreConfig::default())) as Box<dyn ConvBackend>,
        Box::new(GoldenBackend::new()),
        Box::new(Im2colBackend::new(2)),
    ] {
        assert!(capable.capability().supports(JobKind::Depthwise), "{}", capable.name());
        assert!(capable.run(&payload).is_ok(), "{}", capable.name());
    }

    let mut incapable = SimBackend::new(IpCoreConfig {
        mode: AccumMode::Wrap8,
        ..Default::default()
    });
    assert!(!incapable.capability().supports(JobKind::Depthwise));
    assert!(incapable.run(&payload).is_err());
}
