//! Workload traces for the coordinator benches: streams of conv-layer
//! requests with configurable shape mix and arrival pattern.
//!
//! The paper evaluates a single fixed workload (§5.2). A serving system
//! needs mixed traffic, so the trace generator produces the shapes of
//! the edge CNN plus the paper's S52 layer in configurable proportions
//! — DESIGN.md's "synthetic equivalent of production traces".

use super::{network::edge_cnn_specs, LayerSpec, S52};
use crate::util::prng::Prng;

/// One trace entry: which layer shape arrives and when (in microseconds
/// of simulated wall clock from trace start).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TraceEntry {
    pub spec: LayerSpec,
    pub arrival_us: u64,
    pub seed: u64,
}

#[derive(Clone, Debug)]
pub struct TraceConfig {
    /// Total requests to generate.
    pub n: usize,
    /// Mean inter-arrival gap in microseconds (exponential-ish via
    /// uniform doubling; 0 = all arrive at t=0, a closed-loop burst).
    pub mean_gap_us: u64,
    /// Weight of the big S52 layer relative to edge-CNN layers
    /// (0.0 = only small layers, 1.0 = only S52).
    pub s52_fraction: f64,
    pub seed: u64,
}

impl Default for TraceConfig {
    fn default() -> Self {
        TraceConfig {
            n: 64,
            mean_gap_us: 0,
            s52_fraction: 0.25,
            seed: 1,
        }
    }
}

/// Generate a deterministic trace from a config.
pub fn generate(cfg: &TraceConfig) -> Vec<TraceEntry> {
    let mut rng = Prng::new(cfg.seed);
    let small = edge_cnn_specs();
    let mut t = 0u64;
    (0..cfg.n)
        .map(|i| {
            let spec = if rng.f64() < cfg.s52_fraction {
                S52
            } else {
                *rng.choose(&small)
            };
            if cfg.mean_gap_us > 0 {
                // Uniform in [0, 2*mean] has the right mean and keeps the
                // trace integer-deterministic.
                t += rng.below(2 * cfg.mean_gap_us + 1);
            }
            TraceEntry {
                spec,
                arrival_us: t,
                seed: cfg.seed ^ (i as u64) << 1,
            }
        })
        .collect()
}

/// Total PSUMs in a trace (the paper's throughput accounting unit).
pub fn total_psums(trace: &[TraceEntry]) -> u64 {
    trace.iter().map(|e| e.spec.psums()).sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let cfg = TraceConfig::default();
        assert_eq!(generate(&cfg), generate(&cfg));
    }

    #[test]
    fn arrivals_are_monotone() {
        let cfg = TraceConfig {
            mean_gap_us: 100,
            n: 50,
            ..Default::default()
        };
        let t = generate(&cfg);
        for pair in t.windows(2) {
            assert!(pair[0].arrival_us <= pair[1].arrival_us);
        }
    }

    #[test]
    fn fraction_extremes() {
        let only_s52 = generate(&TraceConfig {
            s52_fraction: 1.0,
            ..Default::default()
        });
        assert!(only_s52.iter().all(|e| e.spec == S52));
        let none = generate(&TraceConfig {
            s52_fraction: 0.0,
            ..Default::default()
        });
        assert!(none.iter().all(|e| e.spec != S52));
    }

    #[test]
    fn psum_totals_add_up() {
        let t = generate(&TraceConfig {
            n: 3,
            s52_fraction: 1.0,
            ..Default::default()
        });
        assert_eq!(total_psums(&t), 3 * S52.psums());
    }
}
