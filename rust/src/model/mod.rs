//! CNN model substrate: tensors, layer descriptors, golden reference
//! convolutions (both accumulator modes), int8 quantisation, the edge
//! CNN used by the end-to-end experiments, and workload-trace
//! generation for the coordinator benches.
//!
//! This is the rust mirror of `python/compile/model.py`; the
//! `LayerSpec::name()` string is the join key into the AOT manifest.

pub mod golden;
pub mod im2col;
pub mod mobilenet;
pub mod network;
pub mod quant;
pub mod tensor;
pub mod trace;

pub use golden::{conv3x3_i32, conv3x3_wrap8, maxpool2x2};
pub use network::{EdgeCnn, NetworkParams};
pub use tensor::Tensor;

use crate::paper::{KH, KW};

/// Static shape of one convolutional layer — the coordinator's routing
/// key and the unit of work the paper's IP core processes (§3: "one
/// layer at a time").
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct LayerSpec {
    /// Input channels C.
    pub c: usize,
    /// Input height H.
    pub h: usize,
    /// Input width W.
    pub w: usize,
    /// Kernel count K (= output channels).
    pub k: usize,
    /// Fused ReLU after accumulation.
    pub relu: bool,
    /// 2x2/s2 max pool after the conv.
    pub pool: bool,
}

impl LayerSpec {
    pub const fn new(c: usize, h: usize, w: usize, k: usize) -> Self {
        LayerSpec {
            c,
            h,
            w,
            k,
            relu: false,
            pool: false,
        }
    }

    pub const fn with_relu(mut self) -> Self {
        self.relu = true;
        self
    }

    pub const fn with_pool(mut self) -> Self {
        self.pool = true;
        self
    }

    /// Valid-conv output height before pooling.
    pub fn conv_oh(&self) -> usize {
        self.h - KH + 1
    }

    /// Valid-conv output width before pooling.
    pub fn conv_ow(&self) -> usize {
        self.w - KW + 1
    }

    /// Final output height (after optional pooling).
    pub fn oh(&self) -> usize {
        let oh = self.conv_oh();
        if self.pool {
            oh / 2
        } else {
            oh
        }
    }

    /// Final output width (after optional pooling).
    pub fn ow(&self) -> usize {
        let ow = self.conv_ow();
        if self.pool {
            ow / 2
        } else {
            ow
        }
    }

    /// PSUM count in the paper's §5.2 accounting: one per
    /// (output pixel, kernel, input channel).
    pub fn psums(&self) -> u64 {
        (self.conv_oh() * self.conv_ow() * self.k * self.c) as u64
    }

    /// Multiply-accumulate count (9 MACs per PSUM).
    pub fn macs(&self) -> u64 {
        self.psums() * (KH * KW) as u64
    }

    /// Manifest join key; must match `python/compile/model.py::ConvSpec.name`.
    pub fn name(&self) -> String {
        let tag = if self.pool {
            "p"
        } else if self.relu {
            "r"
        } else {
            "n"
        };
        format!(
            "conv3x3_c{}h{}w{}k{}{}",
            self.c, self.h, self.w, self.k, tag
        )
    }

    /// The paper's §4.1 BRAM layout constraint: channels and kernels
    /// divisible by 4 (first layer excepted for C).
    pub fn paper_compatible(&self) -> bool {
        self.k % 4 == 0 && self.h >= KH && self.w >= KW
    }
}

/// §5.2 headline workload: 224x224x8 feature ⊛ 8x3x3x8 weights.
pub const S52: LayerSpec = LayerSpec::new(8, 224, 224, 8);
/// Quickstart artifact shape.
pub const QUICKSTART: LayerSpec = LayerSpec::new(8, 16, 16, 8);

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn s52_matches_paper_counts() {
        assert_eq!(S52.psums(), 3_154_176);
        assert_eq!(S52.conv_oh(), 222);
        assert_eq!(S52.macs(), 3_154_176 * 9);
    }

    #[test]
    fn names_match_python_convention() {
        assert_eq!(QUICKSTART.name(), "conv3x3_c8h16w16k8n");
        assert_eq!(
            LayerSpec::new(4, 32, 32, 8).with_relu().with_pool().name(),
            "conv3x3_c4h32w32k8p"
        );
        assert_eq!(
            LayerSpec::new(8, 15, 15, 16).with_relu().name(),
            "conv3x3_c8h15w15k16r"
        );
    }

    #[test]
    fn pooled_output_dims_floor() {
        let spec = LayerSpec::new(4, 32, 32, 8).with_pool();
        assert_eq!((spec.conv_oh(), spec.oh()), (30, 15));
        let odd = LayerSpec::new(16, 13, 13, 16).with_pool();
        assert_eq!((odd.conv_oh(), odd.oh()), (11, 5));
    }
}
