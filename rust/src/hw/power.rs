//! Energy model — the paper's motivation is *edge* deployment ("low
//! energy consumption", §2.2), but it reports no energy numbers. This
//! module supplies the missing column: an activity-based energy
//! estimate per layer, built from per-event energies typical of 28 nm
//! (Zynq-7000) and 16 nm (UltraScale+) FPGA fabrics.
//!
//! The absolute picojoule constants are order-of-magnitude literature
//! values (Horowitz ISSCC'14 scaled to FPGA fabric overheads), not
//! measurements — the *relative* story they support (DMA ≪ BRAM ≪ MAC
//! at these shapes; UltraScale+ ≈ 2.5× more efficient) is robust to the
//! constants, and every constant is a named, overridable field.

use super::device::{Device, Family};
use super::dma::DmaStats;
use super::ip_core::CycleStats;
use crate::model::LayerSpec;
use crate::paper::{KH, KW};

/// Per-event energies in picojoules.
#[derive(Clone, Copy, Debug)]
pub struct EnergyModel {
    /// One 8x8 MAC (multiply + add) in fabric logic.
    pub mac_pj: f64,
    /// One BRAM byte read or write.
    pub bram_byte_pj: f64,
    /// One DMA byte moved over AXI to/from DDR.
    pub dma_byte_pj: f64,
    /// Static + clock-tree power per cycle for one IP core, pJ/cycle.
    pub idle_pj_per_cycle: f64,
}

impl EnergyModel {
    /// Literature-scaled defaults per device family.
    pub fn for_family(family: Family) -> Self {
        match family {
            // 28nm fabric: ~4x ASIC energy for logic ops.
            Family::Series7 => EnergyModel {
                mac_pj: 1.2,
                bram_byte_pj: 0.6,
                dma_byte_pj: 20.0, // includes DDR access
                idle_pj_per_cycle: 450.0,
            },
            // 16nm FinFET: roughly 2.5x better logic/BRAM energy.
            Family::UltraScalePlus => EnergyModel {
                mac_pj: 0.5,
                bram_byte_pj: 0.25,
                dma_byte_pj: 12.0,
                idle_pj_per_cycle: 220.0,
            },
        }
    }
}

/// Energy breakdown for one layer run, nanojoules.
#[derive(Clone, Copy, Debug, Default)]
pub struct EnergyReport {
    pub mac_nj: f64,
    pub bram_nj: f64,
    pub dma_nj: f64,
    pub idle_nj: f64,
}

impl EnergyReport {
    pub fn total_nj(&self) -> f64 {
        self.mac_nj + self.bram_nj + self.dma_nj + self.idle_nj
    }

    /// Energy efficiency in the paper's op accounting: PSUMs per µJ.
    pub fn psums_per_uj(&self, psums: u64) -> f64 {
        psums as f64 / (self.total_nj() / 1000.0)
    }
}

/// Estimate the energy of one layer run from its activity counts.
pub fn estimate_layer(
    spec: &LayerSpec,
    cycles: &CycleStats,
    dma: &DmaStats,
    model: &EnergyModel,
) -> EnergyReport {
    let macs = spec.macs() as f64;
    // BRAM traffic: every window fetch + weight load + output RMW.
    // Weight-stationary slide reuse: ~3 image bytes per window after the
    // first column, 9 weights per (group,channel), RMW = 2 accesses of
    // the output word per PSUM.
    let windows = (spec.conv_oh() * spec.conv_ow()) as f64;
    let img_bytes = windows * (spec.c as f64) * 3.2; // slide avg + row restarts
    let wgt_bytes = (spec.k * spec.c * KH * KW) as f64;
    let out_bytes = spec.psums() as f64 * 2.0 * 4.0; // i32 RMW
    let bram_bytes = img_bytes + wgt_bytes + out_bytes;
    EnergyReport {
        mac_nj: macs * model.mac_pj / 1000.0,
        bram_nj: bram_bytes * model.bram_byte_pj / 1000.0,
        dma_nj: dma.bytes as f64 * model.dma_byte_pj / 1000.0,
        idle_nj: cycles.total as f64 * model.idle_pj_per_cycle / 1000.0,
    }
}

/// Device-level convenience: the model for a catalog entry.
pub fn model_for(device: &Device) -> EnergyModel {
    EnergyModel::for_family(device.family)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::device::{XC7Z020_CLG400, XZCU3EG_SBVA484};
    use crate::hw::{IpCore, IpCoreConfig};
    use crate::model::{Tensor, QUICKSTART};
    use crate::util::prng::Prng;

    fn run_quickstart() -> (CycleStats, DmaStats) {
        let spec = QUICKSTART;
        let mut rng = Prng::new(9);
        let img = Tensor::from_vec(
            &[spec.c, spec.h, spec.w],
            rng.bytes_below(spec.c * spec.h * spec.w, 256),
        );
        let wts = Tensor::from_vec(&[spec.k, spec.c, 3, 3], rng.bytes_below(spec.k * spec.c * 9, 256));
        let run = IpCore::new(IpCoreConfig::default())
            .run_layer(&spec, &img, &wts, &vec![0; spec.k], None)
            .unwrap();
        (run.cycles, run.dma)
    }

    #[test]
    fn breakdown_is_positive_and_mac_dominant_on_compute_heavy_layers() {
        let (cycles, dma) = run_quickstart();
        let m = model_for(&XC7Z020_CLG400);
        let e = estimate_layer(&QUICKSTART, &cycles, &dma, &m);
        assert!(e.mac_nj > 0.0 && e.bram_nj > 0.0 && e.dma_nj > 0.0 && e.idle_nj > 0.0);
        assert!(e.total_nj() > e.mac_nj);
        // Compute-heavy layer: MAC + BRAM energy exceeds DMA energy.
        assert!(e.mac_nj + e.bram_nj > e.dma_nj, "{e:?}");
    }

    #[test]
    fn ultrascale_is_more_efficient() {
        let (cycles, dma) = run_quickstart();
        let e7 = estimate_layer(
            &QUICKSTART,
            &cycles,
            &dma,
            &model_for(&XC7Z020_CLG400),
        );
        let eu = estimate_layer(
            &QUICKSTART,
            &cycles,
            &dma,
            &model_for(&XZCU3EG_SBVA484),
        );
        assert!(eu.total_nj() < e7.total_nj());
        assert!(
            eu.psums_per_uj(QUICKSTART.psums()) > e7.psums_per_uj(QUICKSTART.psums()) * 1.5
        );
    }

    #[test]
    fn efficiency_metric_scales_inverse_with_energy() {
        let e = EnergyReport {
            mac_nj: 500.0,
            bram_nj: 300.0,
            dma_nj: 100.0,
            idle_nj: 100.0,
        };
        assert!((e.total_nj() - 1000.0).abs() < 1e-9);
        assert!((e.psums_per_uj(2000) - 2000.0).abs() < 1e-9);
    }
}
